"""Benchmark harness: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary lines per benchmark (the
harness contract), writes full per-figure CSVs to results/benchmarks/, and
validates the paper-claim anchors at the end.

Also includes microbenchmarks of the real compute paths (blocked attention,
WKV chunked scan, MoE dispatch) on CPU — wall-time there is a correctness/
regression signal, not a TPU performance claim.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import time

import numpy as np


def _figure_benchmarks():
    from benchmarks.figures import ALL
    os.makedirs("results/benchmarks", exist_ok=True)
    summary = []
    for name, fn in ALL.items():
        t0 = time.perf_counter()
        header, rows = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        path = f"results/benchmarks/{name}.csv"
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(header)
            w.writerows(rows)
        summary.append((name, dt_us, f"{len(rows)}rows:{path}"))
    return summary


def _micro_benchmarks():
    import jax
    import jax.numpy as jnp
    from repro.models.attention import _attend_blocked
    from repro.models.rwkv6 import wkv_chunked
    from repro.models.layers import Runtime
    key = jax.random.PRNGKey(0)
    out = []

    def timeit(name, fn, *args, n=3, derived=""):
        fn(*args)                      # compile
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        out.append((name, (time.perf_counter() - t0) / n * 1e6, derived))

    # independent keys per tensor: correlated q/k/v make softmax rows
    # degenerate (one dominant logit) and flatter the timings
    kq, kk_, kv_, kr, kw, ku, kx = jax.random.split(key, 7)
    q = jax.random.normal(kq, (2, 1024, 4, 64))
    k = jax.random.normal(kk_, (2, 1024, 2, 64))
    v = jax.random.normal(kv_, (2, 1024, 2, 64))
    f = jax.jit(lambda q, k, v: _attend_blocked(q, k, v, 0, 0.125, 256, 256))
    timeit("micro_blocked_attention_1k", f, q, k, v,
           derived="B2S1024H4GQA2D64_cpu")

    r = jax.random.normal(kr, (2, 512, 4, 64)) * 0.5
    kk = jax.random.normal(kk_, (2, 512, 4, 64)) * 0.5
    vv = jax.random.normal(kv_, (2, 512, 4, 64)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(kw, (2, 512, 4, 64)) - 2.5))
    u = jax.random.normal(ku, (4, 64)) * 0.3
    s0 = jnp.zeros((2, 4, 64, 64))
    g = jax.jit(lambda *a: wkv_chunked(*a, 64))
    timeit("micro_wkv6_chunked_512", g, r, kk, vv, w, u, s0,
           derived="B2T512H4N64_cpu")

    from repro.models import moe as moe_lib
    from repro.configs import get_config, reduced
    cfg = reduced(get_config("deepseek-moe-16b"))
    p = moe_lib.init_moe(cfg, key)
    x = jax.random.normal(kx, (4, 128, cfg.d_model))
    rt = Runtime(moe_impl="dropping", moe_groups=4)
    h = jax.jit(lambda x: moe_lib.apply_moe(cfg, p, x, rt)[0])
    timeit("micro_moe_dispatch", h, x, derived="T512E4k2_cpu")
    return out


# (name, dims) sweep for the kernel fwd / fwd+bwd microbenchmarks: varies
# sequence length, head dim, GQA ratio, and sliding window
KERNEL_SHAPES = [
    ("mha_s256_d64", dict(B=1, S=256, H=4, Kv=4, D=64, window=0)),
    ("gqa4_s512_d64", dict(B=1, S=512, H=8, Kv=2, D=64, window=0)),
    ("mha_s256_d128", dict(B=1, S=256, H=4, Kv=4, D=128, window=0)),
    ("swa128_s512_d64", dict(B=1, S=512, H=4, Kv=2, D=64, window=128)),
]
NORM_SHAPES = [
    ("rows2048_d256", (2048, 256)),
    ("rows4096_d1024", (4096, 1024)),
]


def _kernel_microbenchmarks(out_path: str = "results/benchmarks/BENCH_kernels.json",
                            n_iter: int = 3):
    """Time fwd and fwd+bwd of the attention/rmsnorm hot path for both impls
    (pure-jnp fallback vs Pallas kernels; interpret mode off-TPU) and write
    the perf-trajectory artifact BENCH_kernels.json."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kernel_ops
    from repro.kernels import ref
    from repro.models.attention import _attend_blocked

    def bench(fn, *args):
        fn(*args)                                  # compile / first trace
        jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        for _ in range(n_iter):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / n_iter * 1e6

    records, summary = [], []
    for idx, (name, sh) in enumerate(KERNEL_SHAPES):
        B, S, H, Kv, D, w = (sh[k] for k in ("B", "S", "H", "Kv", "D",
                                             "window"))
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(idx), 3)
        q = jax.random.normal(kq, (B, S, H, D))
        k = jax.random.normal(kk, (B, S, Kv, D))
        v = jax.random.normal(kv, (B, S, Kv, D))
        impls = {
            "jnp": jax.jit(lambda q, k, v, w=w, D=D: _attend_blocked(
                q, k, v, w, D ** -0.5, 128, 128)),
            "pallas": jax.jit(lambda q, k, v, w=w: kernel_ops.attention(
                q, k, v, window=w, block_q=128, block_kv=128)),
        }
        for impl, fwd in impls.items():
            fwd_bwd = jax.jit(jax.grad(
                lambda q, k, v, fwd=fwd: jnp.sum(fwd(q, k, v)),
                argnums=(0, 1, 2)))
            t_fwd = bench(fwd, q, k, v)
            t_bwd = bench(fwd_bwd, q, k, v)
            records.append({"kernel": "attention", "shape": name, **sh,
                            "impl": impl, "fwd_us": round(t_fwd, 1),
                            "fwd_bwd_us": round(t_bwd, 1)})
            summary.append((f"kern_attn_{name}_{impl}", t_fwd,
                            f"fwdbwd{t_bwd:.0f}us"))
    for idx, (name, (n, d)) in enumerate(NORM_SHAPES):
        kx, ks = jax.random.split(jax.random.PRNGKey(100 + idx))
        x = jax.random.normal(kx, (n, d))
        scale = jax.random.normal(ks, (d,))
        impls = {
            "jnp": jax.jit(ref.rmsnorm_ref),
            "pallas": jax.jit(lambda x, s: kernel_ops.rmsnorm(x, s)),
        }
        for impl, fwd in impls.items():
            fwd_bwd = jax.jit(jax.grad(
                lambda x, s, fwd=fwd: jnp.sum(fwd(x, s)), argnums=(0, 1)))
            t_fwd = bench(fwd, x, scale)
            t_bwd = bench(fwd_bwd, x, scale)
            records.append({"kernel": "rmsnorm", "shape": name,
                            "rows": n, "d": d, "impl": impl,
                            "fwd_us": round(t_fwd, 1),
                            "fwd_bwd_us": round(t_bwd, 1)})
            summary.append((f"kern_rmsnorm_{name}_{impl}", t_fwd,
                            f"fwdbwd{t_bwd:.0f}us"))

    out_dir = os.path.dirname(out_path)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"backend": jax.default_backend(),
                   "interpret_mode": jax.default_backend() != "tpu",
                   "n_iter": n_iter, "rows": records}, f, indent=1)
    print(f"[bench] wrote {out_path} ({len(records)} rows)")
    return summary


def _measure_strategy_step(cfg, spec: str, shape, n_iter: int = 3,
                           topo=None):
    """Shared sweep harness: lower ``spec`` for (cfg, host topology),
    execute one compiled train step best-of-``n_iter``, and return
    (strat, report, plan, rt, row) where ``row`` carries the common
    predicted/measured fields — the pp/ep sweeps add their own columns.
    ``topo`` overrides the default all-host-devices topology (the drift
    report measures a 1-device baseline)."""
    import jax
    from repro import strategy as strategy_lib
    from repro.core import parallel as par
    from repro.launch.specs import concrete_train_batch
    from repro.models import transformer as tfm
    from repro.optim import init_opt_state
    from repro.train.trainer import (TrainConfig, make_train_step,
                                     place_train_state)

    topo = topo if topo is not None else strategy_lib.host_topology()
    key = jax.random.PRNGKey(0)
    strat = strategy_lib.parse(spec)
    report = strategy_lib.evaluate(cfg, strat, topo, shape)
    plan = strat.to_plan(cfg, topo, shape)
    # dtypes follow the spec's precision policy (f32 default, _bf16/_fp8
    # opt in) so precision-suffixed specs measure what they claim
    rt = par.make_runtime(cfg, plan, shape, remat=False,
                          attn_min_chunked_len=256)
    params = tfm.init_params(cfg, key)
    batch = concrete_train_batch(cfg, shape.global_batch, shape.seq_len, key)
    with par.use_mesh(plan.mesh):
        params_s, opt_s, batch_s, pshard, _ = place_train_state(
            cfg, plan, params, init_opt_state(params), batch)
        step = jax.jit(make_train_step(cfg, rt, TrainConfig()),
                       out_shardings=(pshard, None, None))
        # AOT-compile once: the executable both runs the timing loop and
        # reports the backend's memory analysis (measured peak memory)
        compiled = step.lower(params_s, opt_s, batch_s).compile()
        jax.block_until_ready(compiled(params_s, opt_s, batch_s))  # warm-up
        t_best = float("inf")
        for _ in range(n_iter):
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(params_s, opt_s, batch_s))
            t_best = min(t_best, time.perf_counter() - t0)
        mem = _compiled_memory(compiled)
    row = {
        "spec": spec,
        "mesh": {k: int(v) for k, v in plan.mesh.shape.items()},
        "precision": strat.precision,
        "predicted_hw": topo.hardware,
        "predicted_t_step_s": report.t_step,
        "measured_t_step_s": round(t_best, 4),
        "measured_backend": jax.default_backend(),
        # compiled-executable memory analysis (None where the backend
        # does not report one): temp = activations/workspace — the term
        # pipeline schedules actually move; args = params + opt state
        "measured_temp_bytes": mem.get("temp"),
        "measured_arg_bytes": mem.get("args"),
    }
    return strat, report, plan, rt, row


def _compiled_memory(compiled) -> dict:
    """Per-device memory analysis of a compiled executable ({} / None
    fields when the backend can't say)."""
    try:
        ma = compiled.memory_analysis()
    except Exception:                      # noqa: BLE001 — best-effort probe
        return {}
    if ma is None:
        return {}
    def _get(attr):
        v = getattr(ma, attr, None)
        return int(v) if v is not None else None
    return {"temp": _get("temp_size_in_bytes"),
            "args": _get("argument_size_in_bytes")}


def _write_bench(out_path: str, payload: dict, n_rows: int):
    out_dir = os.path.dirname(out_path)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[bench] wrote {out_path} ({n_rows} rows)")


def _pp_sweep(out_path: str = "results/benchmarks/BENCH_pipeline.json",
              pps=(1, 2, 4), scheds=("gpipe", "1f1b", "1f1b_i2", "zb"),
              ovls=(False, True), n_iter: int = 3):
    """Predicted vs measured step time for pp in {1,2,4} x schedule in
    {gpipe, 1f1b, 1f1b_i2, zb} x ZeRO gather overlap {off, on} on 8
    virtual CPU devices -> BENCH_pipeline.json (CI artifact).

    Measured wall time is a CPU regression signal; the *comparable*
    quantities across the predicted/measured columns are the per-schedule
    pipeline bubble fraction (schedule-determined and hardware-free —
    (P-1)/(M+P-1) for gpipe/1f1b, (P-1)/(vM+P-1) interleaved,
    2(P-1)/(3M+2P-2) zero-bubble) and the per-schedule peak memory,
    recorded both predicted (cost model) and measured (compiled-executable
    memory analysis, where the backend reports one).  The `_ovl` variants
    flip the double-buffered ZeRO gather prefetch; the bubble probe runs
    once per schedule (the bubble does not depend on the overlap token).
    """
    from repro.launch.devices import force_host_device_count
    force_host_device_count(8)
    import jax
    from repro import strategy as strategy_lib
    from repro.configs import ShapeConfig, get_config, reduced
    from repro.core.pipeline import (inflight_microbatches, op_tick_counts,
                                     virtual_stages)
    from repro.perf.pipeline_probe import measure_bubble

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=8)
    topo = strategy_lib.host_topology()
    shape = ShapeConfig("pp-sweep", 128, 16, "train")
    rows, summary = [], []
    for pp in pps:
        for sched in (scheds if pp > 1 else ("gpipe",)):
            for ovl in ovls:
                rows.append(_pp_sweep_point(
                    cfg, topo, shape, pp, sched, ovl, n_iter, summary,
                    inflight_microbatches, op_tick_counts, virtual_stages,
                    measure_bubble))
    _write_bench(out_path, {
        "backend": jax.default_backend(), "n_iter": n_iter,
        "arch": cfg.name, "shape": {"seq_len": shape.seq_len,
                                    "global_batch": shape.global_batch},
        "rows": rows}, len(rows))
    return summary


def _pp_sweep_point(cfg, topo, shape, pp, sched, ovl, n_iter, summary,
                    inflight_microbatches, op_tick_counts, virtual_stages,
                    measure_bubble):
    """One (pp, sched, ovl) row of the pipeline sweep."""
    if pp == 1:
        spec = "fsdp" + ("_ovl" if ovl else "")
    else:
        spec = f"fsdp_pp{pp}_mb8" \
            + ("" if sched == "gpipe" else f"_{sched}") \
            + ("_ovl" if ovl else "")
    strat, report, _plan, _rt, row = _measure_strategy_step(
        cfg, spec, shape, n_iter)
    t_best = row["measured_t_step_s"]
    row.update(pp=pp, microbatches=strat.microbatches, sched=sched,
               overlap=ovl, virtual_stages=virtual_stages(sched),
               predicted_wps=report.wps,
               predicted_peak_memory_bytes=report.memory_per_device)
    if pp > 1:
        row["inflight_microbatches"] = inflight_microbatches(
            pp, strat.microbatches, sched)
        row["op_tick_counts"] = op_tick_counts(
            sched, pp, strat.microbatches)
        if not ovl:
            row.update(measure_bubble(cfg, strat, topo, n_iter=n_iter))
            if row.get("fit_unreliable"):
                # the two-point fit came out non-increasing — a failed
                # measurement: no rel_err is recorded (a clamped 0.0
                # would fabricate a 100% miss), only the flag
                row["bubble_rel_err"] = None
                print(f"[bench] warn: {spec} bubble fit unreliable "
                      "(t(2M) <= t(M); noisy host) — row flagged")
                rel = 0.0
            else:
                rel = abs(row["bubble_measured"]
                          - row["bubble_predicted"]) \
                    / row["bubble_predicted"]
                row["bubble_rel_err"] = round(rel, 3)
            if not row.get("fit_unreliable") and rel > 0.2:
                # two-point wall-clock fits are noisy on oversubscribed
                # CPU hosts; flag it so the artifact is self-describing
                # (the tier-1 slow test enforces the 20% bound with
                # retries; this sweep only records the trajectory)
                print(f"[bench] warn: {spec} measured bubble "
                      f"{row['bubble_measured']:.3f} is {rel:.0%} off "
                      f"the predicted {row['bubble_predicted']:.3f} "
                      "(noisy host?)")
    summary.append((f"pp_sweep_{spec}", t_best * 1e6,
                    f"bubble{row.get('bubble_measured', 0.0):.3f}"
                    f"_pred{row.get('bubble_predicted', 0.0):.3f}"
                    f"_mem{row['predicted_peak_memory_bytes']/2**20:.0f}MiB"))
    return row


def _ep_sweep(out_path: str = "results/benchmarks/BENCH_moe.json",
              eps=(1, 2, 4, 8), n_iter: int = 3):
    """Predicted vs measured MoE step time across ep in {1,2,4,8} on 8
    virtual CPU devices -> BENCH_moe.json (CI artifact).

    Records the analytic step time and the exposed `moe_a2a` fraction per
    ep degree next to the executed wall time of the EP shard_map dispatch.
    Wall time on CPU is a regression signal, not a TPU claim; the
    comparable trend is the a2a fraction trading against the shrinking
    expert-param gathers as ep grows.
    """
    import dataclasses
    from repro.launch.devices import force_host_device_count
    force_host_device_count(8)
    import jax
    from repro.configs import ShapeConfig, get_config, reduced

    # 8 routed experts so every ep in the sweep divides the expert count
    cfg = reduced(get_config("deepseek-moe-16b"), max_experts=8)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, moe_start_layer=0))
    shape = ShapeConfig("ep-sweep", 128, 16, "train")
    rows, summary = [], []
    for ep in eps:
        spec = "fsdp" if ep == 1 else f"fsdp_ep{ep}"
        _strat, report, _plan, rt, row = _measure_strategy_step(
            cfg, spec, shape, n_iter)
        a2a = report.comm_breakdown["moe_a2a"]
        row.update(
            ep=ep, moe_impl=rt.moe_impl,
            predicted_moe_a2a_s=a2a,
            predicted_exposed_a2a_frac=0.5 * a2a / report.t_step,
            predicted_fsdp_ag_s=report.comm_breakdown["fsdp_ag"])
        rows.append(row)
        summary.append((f"ep_sweep_{spec}", row["measured_t_step_s"] * 1e6,
                        f"a2afrac{row['predicted_exposed_a2a_frac']:.3f}"
                        f"_impl{rt.moe_impl}"))
    _write_bench(out_path, {
        "backend": jax.default_backend(), "n_iter": n_iter,
        "arch": cfg.name, "n_experts": cfg.moe.n_experts,
        "shape": {"seq_len": shape.seq_len,
                  "global_batch": shape.global_batch},
        "rows": rows}, len(rows))
    return summary


def _serve_sweep(out_path: str = "results/benchmarks/BENCH_serve.json",
                 batches=(1, 2, 4, 8), prompt_len: int = 16,
                 n_new: int = 64, n_iter: int = 3):
    """Serving-engine sweep: continuous-batching paged engine vs the
    static dense-cache baseline across offered batch sizes ->
    BENCH_serve.json (CI artifact).

    Per batch size it records, for both engines, end-to-end tokens/s and
    the p50/p99 *effective per-token latency* (continuous: each token's
    share of the wall time of the tick that delivered it; static: the
    wall time of each synchronized host step).  It also isolates the
    decode inner-loop dispatch comparison the paged engine is built
    around: the same jitted paged decode kernel run as one on-device
    ``lax.fori_loop`` segment of ``steps`` iterations vs ``steps``
    single-step host dispatches over identical mid-flight state.  CPU
    wall time is a regression signal, not a TPU claim; the dispatch
    ratio is the comparable trend.
    """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import transformer as tfm
    from repro.models.layers import Runtime
    from repro.serve import ServeEngine

    # small enough that per-step dispatch overhead is visible next to
    # compute — the regime where the on-device segment loop matters
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=2, d_model=128)
    rt = Runtime()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    steps = 32
    rows, summary = [], []
    for B in batches:
        eng = ServeEngine(cfg, params, rt, max_len=prompt_len + n_new + 8,
                          n_slots=B, block_size=16, prefill_chunk=prompt_len,
                          steps_per_tick=steps)
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (B, prompt_len), 0, cfg.vocab_size)
        pnp = np.asarray(prompts)
        eng.generate(prompts, n_new)             # compile the paged path
        eng.generate_static(prompts, n_new)      # compile the dense path

        # -- continuous: timed tick loop over the paged engine ----------
        def run_continuous():
            for i in range(B):
                eng.submit(pnp[i], n_new, stream=i)
            base = eng._base_key(None)
            sched = eng._sched
            lat, n_ticks = [], 0
            t0 = time.perf_counter()
            while sched.has_work():
                gen0 = {r.rid: len(r.generated)
                        for r in sched.running.values()}
                t1 = time.perf_counter()
                eng._tick(base)
                wall = time.perf_counter() - t1
                n_ticks += 1
                for r in list(sched.running.values()) + \
                        list(sched.finished.values()):
                    g = len(r.generated) - gen0.get(r.rid, 0)
                    if g:
                        lat += [wall / g] * g
            t_total = time.perf_counter() - t0
            sched.finished.clear()
            return t_total, lat, n_ticks

        t_cont, lat, n_ticks = min(
            (run_continuous() for _ in range(n_iter)), key=lambda r: r[0])
        cont = {"batch": B, "mode": "continuous",
                "tokens_per_s": round(B * n_new / t_cont, 1),
                "p50_token_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
                "p99_token_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
                "total_s": round(t_cont, 4), "n_ticks": n_ticks}

        # -- decode dispatch: on-device segment vs per-step host loop ---
        # identical mid-flight paged state (all B slots decode-active
        # after one tick), identical kernel, identical token count
        for i in range(B):
            eng.submit(pnp[i], n_new, stream=i)
        eng._tick(eng._base_key(None))
        cache = eng._cache_dict()
        last = jnp.asarray(eng._last)
        streams = jnp.asarray(eng._streams)
        temps = jnp.asarray(eng._temps)
        kseg = jax.random.PRNGKey(7)

        def seg(c, l, rem, n):
            return eng._segment_fn(eng.params, c, l,
                                   jnp.full((B,), rem, jnp.int32),
                                   streams, temps, kseg, steps=n)

        jax.block_until_ready(seg(cache, last, 1, 1)[1])   # compile steps=1
        t_dev = t_host = float("inf")
        for _ in range(n_iter):
            t1 = time.perf_counter()
            jax.block_until_ready(seg(cache, last, steps, steps)[1])
            t_dev = min(t_dev, time.perf_counter() - t1)
            t1 = time.perf_counter()
            c, l = cache, last
            for _ in range(steps):
                c, out = seg(c, l, 1, 1)
                l = out[:, 0]
            jax.block_until_ready(l)
            t_host = min(t_host, time.perf_counter() - t1)
        eng.run_until_drained()                  # leave the engine clean
        cont.update(
            decode_on_device_ms_per_step=round(t_dev / steps * 1e3, 4),
            decode_host_dispatch_ms_per_step=round(t_host / steps * 1e3, 4),
            decode_dispatch_speedup=round(t_host / t_dev, 3))

        # -- static baseline: batch prefill + one host step per token ---
        t_stat = float("inf")
        for _ in range(n_iter):
            t0 = time.perf_counter()
            jax.block_until_ready(eng.generate_static(prompts, n_new))
            t_stat = min(t_stat, time.perf_counter() - t0)
        # per-token latency needs per-step walls -> synchronized replay
        logits, cache = eng._prefill(eng.params, {"tokens": prompts})
        last = jax.block_until_ready(logits[:, -1])
        walls = []
        for t in range(n_new):
            t1 = time.perf_counter()
            nxt = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            logits, cache = eng._step(eng.params, cache, nxt,
                                      jnp.asarray(prompt_len + t, jnp.int32))
            last = jax.block_until_ready(logits[:, 0])
            walls.append(time.perf_counter() - t1)
        stat = {"batch": B, "mode": "static",
                "tokens_per_s": round(B * n_new / t_stat, 1),
                "p50_token_ms": round(float(np.percentile(walls, 50)) * 1e3, 3),
                "p99_token_ms": round(float(np.percentile(walls, 99)) * 1e3, 3),
                "total_s": round(t_stat, 4)}
        rows += [cont, stat]
        summary.append((f"serve_b{B}_continuous", t_cont * 1e6,
                        f"tok/s{cont['tokens_per_s']:.0f}"
                        f"_p50{cont['p50_token_ms']:.2f}ms"
                        f"_devstep{cont['decode_on_device_ms_per_step']:.2f}ms"
                        f"_hoststep{cont['decode_host_dispatch_ms_per_step']:.2f}ms"))
        summary.append((f"serve_b{B}_static", t_stat * 1e6,
                        f"tok/s{stat['tokens_per_s']:.0f}"
                        f"_p50{stat['p50_token_ms']:.2f}ms"))
        if B >= 4 and t_host <= t_dev:
            print(f"[bench] warn: B={B} on-device segment "
                  f"({t_dev/steps*1e3:.3f}ms/step) did not beat host "
                  f"dispatch ({t_host/steps*1e3:.3f}ms/step) — noisy host?")

    import jax as _jax
    _write_bench(out_path, {
        "backend": _jax.default_backend(), "arch": cfg.name,
        "prompt_len": prompt_len, "n_new": n_new,
        "steps_per_tick": steps, "block_size": 16,
        "prefill_chunk": prompt_len, "n_iter": n_iter,
        "rows": rows}, len(rows))
    return summary


def _goodput_sweep(out_path: str = "results/benchmarks/BENCH_goodput.json",
                   n_devices=(256, 512, 1024, 2048, 4096, 8192),
                   mtbfs=(0.0, 1.8e8, 3e6, 1e6)):
    """Failure-aware diminishing returns -> BENCH_goodput.json (CI artifact).

    Two halves:

    * **analytic**: effective tokens/s vs device count for llama2-7b on
      H100 islands, with and without failures at swept per-device MTBFs
      (0.0 = no failures).  At each point the planner picks its best
      strategy under both 'wps' and 'effective_wps' over the
      {hsdp, fsdp} dp-mode sweep — where the picks differ, the goodput
      objective changed the sharding decision (few checkpoint writers
      vs many), the paper's diminishing-returns curve bending further
      down once failures are priced.
    * **measured**: per-step checkpoint stall of the sync writer vs the
      AsyncCheckpointer (snapshot-only stall) for a real train state on
      the host devices — the number that justifies ``--async_ckpt``.
    """
    import dataclasses as _dc
    import shutil
    import tempfile

    from repro.launch.devices import force_host_device_count
    force_host_device_count(8)
    import jax
    from repro import strategy as strategy_lib
    from repro.configs import ShapeConfig, get_config
    from repro.core import costmodel as cm

    cfg = get_config("llama2-7b")
    shape = ShapeConfig("goodput-sweep", 4096, 1024, "train")
    modes = ("hsdp", "fsdp")
    rows, summary = [], []
    n_flips = 0
    for mtbf in mtbfs:
        for n in n_devices:
            hw = cm.HARDWARE["H100"]
            if mtbf:
                hw = _dc.replace(hw, mtbf=mtbf)
            topo = strategy_lib.Topology("goodput", n, hw.island,
                                         hardware="H100", hbm=80e9,
                                         hw_obj=hw if mtbf else None)
            a = strategy_lib.best(cfg, topo, shape, objective="wps",
                                  dp_modes=modes)
            b = strategy_lib.best(cfg, topo, shape,
                                  objective="effective_wps", dp_modes=modes)
            if a is None or b is None:
                continue
            r = b.report
            eff = r.wps * (r.goodput_frac if mtbf else 1.0)
            flip = a.spec != b.spec
            n_flips += flip
            rows.append({
                "mtbf_device_s": mtbf or None,   # None = failure-free
                "n_devices": n,
                "wps_pick": a.spec, "effective_pick": b.spec,
                "objectives_disagree": flip,
                "wps": a.report.wps,
                "effective_wps": eff,
                "goodput": r.goodput_frac if mtbf else 1.0,
                "t_ckpt_s": r.t_ckpt,
                "young_daly_interval_s": r.ckpt_interval,
                "distinct_writers": cm.distinct_writers(
                    b.strategy.to_cost_strategy(cfg, topo)),
            })
    # measured: sync full-write stall vs async snapshot-only stall
    from repro import checkpointing as ckpt_lib
    key = jax.random.PRNGKey(0)
    state = {"params": {f"w{i}": jax.random.normal(
        jax.random.fold_in(key, i), (256, 256)) for i in range(8)}}
    tmp = tempfile.mkdtemp(prefix="goodput-bench-")
    try:
        t0 = time.perf_counter()
        ckpt_lib.save_checkpoint(os.path.join(tmp, "sync"), 1, state)
        t_sync = time.perf_counter() - t0
        with ckpt_lib.AsyncCheckpointer(os.path.join(tmp, "async")) as ck:
            t_async = ck.save(1, state)
            ck.wait()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    measured = {
        "state_bytes": int(sum(a.size * a.dtype.itemsize for a in
                               jax.tree.leaves(state))),
        "sync_save_stall_s": round(t_sync, 5),
        "async_save_stall_s": round(t_async, 5),
        "async_stall_fraction": round(t_async / max(t_sync, 1e-9), 4),
    }
    _write_bench(out_path, {
        "backend": jax.default_backend(), "arch": cfg.name,
        "shape": {"seq_len": shape.seq_len,
                  "global_batch": shape.global_batch},
        "hardware": "H100", "dp_modes": list(modes),
        "objective_flips": n_flips,
        "rows": rows, "checkpoint_stall": measured}, len(rows))
    summary.append(("goodput_sweep_flips", float(n_flips),
                    f"{len(rows)}pts_async_stall"
                    f"{measured['async_stall_fraction']:.3f}x_sync"))
    return summary


def _precision_sweep(out_path: str = "results/benchmarks/BENCH_precision.json",
                     n_iter: int = 3):
    """Mixed-precision sweep -> BENCH_precision.json (CI artifact).

    Three sections:

    * **measured**: the same FSDP mesh executed under the _f32 / _bf16 /
      _fp8 precision policies on 8 virtual host devices.  CPU wall time
      is a regression signal, not a TPU claim — what the section proves
      is that each policy lowers and runs end-to-end (bf16 compute with
      f32 master params; fp8 additionally quantizing the per-layer ZeRO
      gather wire) and that the loss stays finite.
    * **kernels**: Pallas flash-attention / rmsnorm fwd at f32 vs bf16
      inputs with the dtype-resolved block defaults (bf16 doubles the
      tile: same VMEM footprint, half the grid steps).
    * **analytic**: the dtype-aware cost model pricing llama2-7b on a
      TPU v5e pod per precision — the byte terms that move the paper's
      EP/PP/FSDP crossovers when precision changes — plus the spec the
      planner picks once precision is a swept degree (bf16 dominates f32
      on any fixed mesh: half the wire bytes, double the matmul rate).
    """
    import dataclasses as _dc

    from repro.launch.devices import force_host_device_count
    force_host_device_count(8)
    import jax
    import jax.numpy as jnp
    from repro import strategy as strategy_lib
    from repro.configs import ShapeConfig, get_config, reduced
    from repro.core import costmodel as cm
    from repro.kernels import ops as kernel_ops

    rows, summary = [], []

    # -- measured: one mesh, three policies -----------------------------
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=4)
    shape = ShapeConfig("precision-sweep", 128, 16, "train")
    for spec in ("fsdp", "fsdp_bf16", "fsdp_fp8"):
        strat, report, plan, rt, row = _measure_strategy_step(
            cfg, spec, shape, n_iter)
        row.update(section="measured",
                   compute_dtype=str(rt.compute_dtype),
                   comm_dtype=plan.policy.comm_dtype
                   or str(jnp.dtype(rt.param_dtype)),
                   predicted_wps=report.wps)
        rows.append(row)
        summary.append((f"precision_step_{spec}",
                        row["measured_t_step_s"] * 1e6,
                        f"compute{row['compute_dtype']}"
                        f"_comm{row['comm_dtype']}"))

    # -- kernels: dtype-resolved block defaults -------------------------
    def bench(fn, *args):
        fn(*args)                                  # compile / first trace
        jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        for _ in range(n_iter):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / n_iter * 1e6

    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    for dt in (jnp.float32, jnp.bfloat16):
        name = str(jnp.dtype(dt))
        q = jax.random.normal(kq, (1, 512, 4, 64), dt)
        k = jax.random.normal(kk, (1, 512, 2, 64), dt)
        v = jax.random.normal(kv, (1, 512, 2, 64), dt)
        t_attn = bench(jax.jit(
            lambda q, k, v: kernel_ops.attention(q, k, v)), q, k, v)
        x = jax.random.normal(kq, (2048, 256), dt)
        s = jax.random.normal(kk, (256,), dt)
        t_norm = bench(jax.jit(
            lambda x, s: kernel_ops.rmsnorm(x, s)), x, s)
        rows.append({"section": "kernels", "dtype": name,
                     "block_q": kernel_ops._dtype_blocks(dt, 128),
                     "block_kv": kernel_ops._dtype_blocks(dt, 256),
                     "block_rows": kernel_ops._dtype_blocks(dt, 256),
                     "attn_fwd_us": round(t_attn, 1),
                     "rmsnorm_fwd_us": round(t_norm, 1)})
        summary.append((f"precision_kern_{name}", t_attn,
                        f"rms{t_norm:.0f}us"
                        f"_bq{kernel_ops._dtype_blocks(dt, 128)}"))

    # -- analytic: dtype-aware byte terms + planner pick -----------------
    cfg7 = get_config("llama2-7b")
    hw = cm.HARDWARE["TPUv5e"]
    for prec in ("f32", "bf16", "fp8"):
        s = _dc.replace(cm.Strategy(256, zero_stage=3), precision=prec)
        r = cm.step_time(cfg7, hw, s, 1024, 2048)
        rows.append({"section": "analytic", "precision": prec,
                     "arch": cfg7.name, "hardware": hw.name,
                     "n_devices": 256, "zero_stage": 3,
                     "t_step_s": r.t_step, "mfu": r.mfu,
                     "fsdp_ag_s": r.comm_breakdown["fsdp_ag"],
                     "fsdp_rs_s": r.comm_breakdown["fsdp_rs"],
                     "memory_per_device": r.memory_per_device})
        summary.append((f"precision_analytic_{prec}", r.t_step * 1e6,
                        f"mfu{r.mfu:.3f}"
                        f"_ag{rows[-1]['fsdp_ag_s'] * 1e3:.1f}ms"))
    topo = strategy_lib.Topology("v5e-pod", 256, hw.island,
                                 hardware=hw.name, hbm=16e9)
    shape7 = ShapeConfig("precision-analytic", 2048, 1024, "train")
    pick = strategy_lib.best(cfg7, topo, shape7)
    if pick is not None:
        rows.append({"section": "planner", "pick": pick.spec,
                     "precision": pick.strategy.precision,
                     "wps": pick.report.wps, "mfu": pick.report.mfu})
        summary.append(("precision_planner_pick", pick.report.t_step * 1e6,
                        pick.spec))

    _write_bench(out_path, {
        "backend": jax.default_backend(), "n_iter": n_iter,
        "measured_arch": cfg.name, "analytic_arch": cfg7.name,
        "rows": rows}, len(rows))
    return summary


def _strategy_benchmark(spec: str, hw_name: str, gpus: int, global_batch: int,
                        seq_len: int):
    """Price one spec (or the planner's 'auto' pick) via the unified API."""
    from repro import strategy as strategy_lib
    from repro.configs.base import ShapeConfig
    from repro.configs.llama2 import LLAMA2_7B
    from repro.core import costmodel as cm
    hw = cm.HARDWARE[hw_name]
    topo = strategy_lib.Topology(hw.name, gpus, island=hw.island,
                                 hardware=hw.name, hbm=80e9)
    shape = ShapeConfig("bench", seq_len, global_batch, "train")
    t0 = time.perf_counter()
    strat, planned = strategy_lib.resolve(spec, LLAMA2_7B, topo, shape)
    r = (planned.report if planned is not None
         else strategy_lib.evaluate(LLAMA2_7B, strat, topo, shape))
    dt_us = (time.perf_counter() - t0) * 1e6
    return [("strategy_" + strat.format(), dt_us,
             f"{hw_name}x{gpus}_wps{r.wps:.0f}_mfu{r.mfu:.3f}")]


def _drift_report(out_path: str = "results/benchmarks/BENCH_drift.json",
                  tel_dir: str = "results/telemetry",
                  specs=("fsdp", "fsdp_tp2"), n_iter: int = 3):
    """Predicted-vs-measured drift per cost-model term — the measured
    half of the measure<->model calibration loop (ROADMAP item).

    Differential probe on 8 virtual CPU devices: the same reduced model
    runs one optimizer step (a) on a **single** device (no collectives —
    its wall time stands in for the measured compute term) and (b) under
    each sharded spec.  measured collective ~= t_spec - t_single, the
    same two-point logic as the pipeline bubble probe.  Each spec's
    :class:`repro.telemetry.DriftMonitor` diffs that against
    ``StepReport.decomposition()`` and the per-term
    ``predicted_over_measured`` ratios land in BENCH_drift.json plus
    per-spec reports, a JSONL event stream, and a Perfetto trace under
    ``results/telemetry/`` (CI schema-checks and uploads them).

    On CPU hosts the *ratios* are apples-to-oranges against the H100
    profile (that gap is exactly what hardware-profile calibration will
    fit); what must hold structurally is that both compute and
    collective terms get a measured value and a ratio.
    """
    from repro.launch.devices import force_host_device_count
    force_host_device_count(8)
    import jax
    from repro import strategy as strategy_lib
    from repro import telemetry as tel
    from repro.configs import ShapeConfig, get_config, reduced

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=4)
    shape = ShapeConfig("drift", 128, 16, "train")
    os.makedirs(tel_dir, exist_ok=True)
    recorder = tel.Recorder()
    recorder.add_sink(tel.JsonlSink(
        os.path.join(tel_dir, "drift_events.jsonl")))
    recorder.add_sink(tel.ChromeTraceSink(
        os.path.join(tel_dir, "drift_trace.json"),
        process_name="drift-report"))

    topo1 = strategy_lib.host_topology(n_devices=1)
    with recorder.span("drift/baseline_1dev"):
        _, _, _, _, row1 = _measure_strategy_step(cfg, "ddp", shape,
                                                  n_iter, topo=topo1)
    t_single = row1["measured_t_step_s"]
    recorder.gauge("drift/measured_compute_s", t_single)

    rows, summary = [], []
    for spec in specs:
        with recorder.span("drift/measure", spec=spec):
            strat, report, plan, rt, row = _measure_strategy_step(
                cfg, spec, shape, n_iter)
        t_spec = row["measured_t_step_s"]
        coll_raw = t_spec - t_single
        measured = {
            "step": t_spec,
            "compute": t_single,
            # floored so the collective term always yields a ratio; the
            # raw (possibly ~0) delta is recorded alongside
            "collective": max(coll_raw, 1e-6),
        }
        monitor = tel.DriftMonitor(
            report.decomposition(), telemetry=recorder,
            meta={"spec": spec, "arch": cfg.name,
                  "predicted_hw": row["predicted_hw"],
                  "measured_backend": row["measured_backend"],
                  "probe": "differential-1dev-baseline",
                  "n_iter": n_iter})
        window = monitor.observe(measured, n_steps=n_iter)
        monitor.write(os.path.join(tel_dir, f"drift_{spec}.json"))
        ratios = window["predicted_over_measured"]
        row.update(measured_compute_s=t_single,
                   measured_collective_raw_s=round(coll_raw, 6),
                   predicted=report.decomposition(),
                   measured=measured,
                   predicted_over_measured=ratios)
        rows.append(row)
        summary.append((
            f"drift_{spec}", t_spec * 1e6,
            "pred/meas:" + ";".join(
                f"{t}={ratios[t]:.3g}" for t in
                ("step", "compute", "collective") if ratios.get(t))))
    recorder.close()
    _write_bench(out_path, {
        "backend": jax.default_backend(),
        "baseline_spec": "ddp@1dev",
        "baseline_t_step_s": t_single,
        "telemetry_dir": tel_dir,
        "rows": rows}, len(rows))
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="auto",
                    help="'auto' (planner pick) or a spec string like "
                         "hsdp_tp4 / fsdp_cp8 to price on --hw x --gpus")
    ap.add_argument("--hw", default="H100")
    ap.add_argument("--gpus", type=int, default=2048)
    ap.add_argument("--global_batch", type=int, default=4096)
    ap.add_argument("--seq_len", type=int, default=4096)
    ap.add_argument("--micro-kernels", dest="micro_kernels",
                    action="store_true",
                    help="only run the fwd/fwd+bwd kernel microbenchmarks "
                         "(jnp vs pallas) and write BENCH_kernels.json")
    ap.add_argument("--kernel_json",
                    default="results/benchmarks/BENCH_kernels.json")
    ap.add_argument("--pp-sweep", dest="pp_sweep", action="store_true",
                    help="only run the pipeline-parallel sweep (predicted "
                         "vs measured step time + per-schedule bubble and "
                         "predicted+measured peak memory for pp in {1,2,4} "
                         "x {gpipe,1f1b,1f1b_i2,zb} x overlap {off,on} on "
                         "8 virtual devices) and write BENCH_pipeline.json")
    ap.add_argument("--pipeline_json",
                    default="results/benchmarks/BENCH_pipeline.json")
    ap.add_argument("--ep-sweep", dest="ep_sweep", action="store_true",
                    help="only run the expert-parallel sweep (predicted "
                         "vs measured step time + exposed moe_a2a "
                         "fraction for ep in {1,2,4,8} on 8 virtual "
                         "devices) and write BENCH_moe.json")
    ap.add_argument("--moe_json",
                    default="results/benchmarks/BENCH_moe.json")
    ap.add_argument("--serve-sweep", dest="serve_sweep", action="store_true",
                    help="only run the serving-engine sweep (continuous-"
                         "batching paged engine vs static dense baseline: "
                         "tokens/s, p50/p99 per-token latency, and the "
                         "on-device decode segment vs per-step host "
                         "dispatch comparison) and write BENCH_serve.json")
    ap.add_argument("--serve_json",
                    default="results/benchmarks/BENCH_serve.json")
    ap.add_argument("--goodput-sweep", dest="goodput_sweep",
                    action="store_true",
                    help="only run the failure-aware goodput sweep "
                         "(analytic effective tokens/s vs device count "
                         "w/wo failures at swept MTBFs, planner picks "
                         "under wps vs effective_wps, and the measured "
                         "async-vs-sync checkpoint stall) and write "
                         "BENCH_goodput.json")
    ap.add_argument("--goodput_json",
                    default="results/benchmarks/BENCH_goodput.json")
    ap.add_argument("--precision-sweep", dest="precision_sweep",
                    action="store_true",
                    help="only run the mixed-precision sweep (f32/bf16/fp8 "
                         "train-step execution on one mesh, dtype-tuned "
                         "kernel blocks, and the dtype-aware cost-model "
                         "column with the planner's precision pick) and "
                         "write BENCH_precision.json")
    ap.add_argument("--precision_json",
                    default="results/benchmarks/BENCH_precision.json")
    ap.add_argument("--drift-report", dest="drift_report",
                    action="store_true",
                    help="only run the predicted-vs-measured drift probe "
                         "(cost-model step/compute/collective terms vs a "
                         "differential 1-device-baseline measurement on 8 "
                         "virtual devices) and write BENCH_drift.json + "
                         "results/telemetry/ artifacts")
    ap.add_argument("--drift_json",
                    default="results/benchmarks/BENCH_drift.json")
    ap.add_argument("--telemetry_dir", default="results/telemetry")
    args = ap.parse_args()

    if args.micro_kernels:
        rows = _kernel_microbenchmarks(args.kernel_json)
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        return

    if args.pp_sweep:
        rows = _pp_sweep(args.pipeline_json)
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        return

    if args.ep_sweep:
        rows = _ep_sweep(args.moe_json)
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        return

    if args.serve_sweep:
        rows = _serve_sweep(args.serve_json)
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        return

    if args.goodput_sweep:
        rows = _goodput_sweep(args.goodput_json)
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        return

    if args.precision_sweep:
        rows = _precision_sweep(args.precision_json)
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        return

    if args.drift_report:
        rows = _drift_report(args.drift_json, args.telemetry_dir)
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        return

    rows = _figure_benchmarks()
    rows += _micro_benchmarks()
    rows += _strategy_benchmark(args.strategy, args.hw, args.gpus,
                                args.global_batch, args.seq_len)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    # paper-claim anchor validation (same checks as tests/test_costmodel.py)
    from repro.configs.llama2 import LLAMA2_7B
    from repro.core import costmodel as cm
    r128 = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(128, zero_stage=2),
                        256, 4096)
    r2048 = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(2048, zero_stage=2),
                         4096, 4096)
    drop = 1 - r2048.tflops_per_device / r128.tflops_per_device
    pdrop = 1 - r2048.power_per_device / r128.power_per_device
    base = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(2048, zero_stage=2),
                        4096, 4096)
    tpgain = max(cm.step_time(LLAMA2_7B, cm.H100,
                              cm.Strategy(2048, tp=tp, zero_stage=2),
                              4096, 4096).wps for tp in (2, 4)) / base.wps - 1
    print(f"claim_weak_scaling_drop,{drop:.4f},paper=0.3722")
    print(f"claim_power_drop,{pdrop:.4f},paper=0.0587")
    print(f"claim_tp_gain_2048,{tpgain:.4f},paper=0.5260")


if __name__ == "__main__":
    main()
