"""One benchmark per paper table/figure, driven by the calibrated cost model
(core/costmodel.py).  Each function returns (header, rows); run.py prints
them as CSV and checks the paper-claim anchors.

Figure map:
  fig2_collectives      — NCCL AllReduce (tree) vs AllGather (ring) busbw
  fig3_weak_scaling     — Llama-7B FSDP, lb=2, 8 -> 2048 H100s
  fig4_collective_time  — AG/RS execution time vs world size
  fig5_strong_scaling   — fixed global batch 32, 2 -> 32 nodes
  fig6_parallelism_sweep— tp x pp search, 256 GPUs, gb=512
  fig7_hw_generations   — A100 vs H100 (and V100, App. F) sweeps
  fig8_model_size       — 1B/7B/13B/70B optimal strategies
  fig9_context_length   — seq 1k -> 16k overlap
  fig11_pretrain_scale  — 7B/70B at 512 -> 2048 GPUs, fixed workload
  fig12_context_parallel— CP vs TP at seq 4096
  fig14_memory          — per-GPU memory vs DP degree
  fig1_power            — tokens/J and power draw vs scale
  tpu_v5e_transfer      — the paper's sweep transferred to the TPU target
"""
from __future__ import annotations

from repro import strategy as strategy_lib
from repro.configs.base import ShapeConfig
from repro.configs.llama2 import LLAMA2_1B, LLAMA2_7B, LLAMA2_13B, LLAMA2_70B
from repro.core import costmodel as cm


def _topo(hw: cm.Hardware, n: int, hbm: float = 80e9) -> strategy_lib.Topology:
    return strategy_lib.Topology(hw.name, n, island=hw.island,
                                 hardware=hw.name, hbm=hbm)


def _search(model, hw, n, global_batch, seq_len, zero_stage=2,
            pps=(1, 2, 4, 8, 16), cps=(1, 2, 4, 8), **kw):
    """Planner sweep used by the figure benchmarks (tp x pp x cp)."""
    shape = ShapeConfig("fig", seq_len, global_batch, "train")
    return strategy_lib.search(
        model, _topo(hw, n), shape, dp_modes=("fsdp",),
        zero_stages=(zero_stage,), pps=pps, cps=cps,
        require_fits=False, require_lowerable=False, **kw)


def fig2_collectives():
    header = ["op", "world_size_gpus", "msg_bytes", "busbw_GBs"]
    rows = []
    for n_nodes in (4, 8, 16, 32, 64, 128, 256, 512):
        n = n_nodes * 8
        for b in (64e6, 512e6):
            rows.append(["allreduce_tree", n, int(b),
                         round(cm.bus_bandwidth_allreduce(cm.H100, b, n) / 1e9, 2)])
            rows.append(["allgather_ring", n, int(b),
                         round(cm.bus_bandwidth_allgather(cm.H100, b, n) / 1e9, 2)])
    return header, rows


def fig3_weak_scaling():
    header = ["gpus", "wps_per_dev", "wps_global", "tflops_per_dev", "mfu",
              "exposed_ms", "power_W", "tokens_per_J", "ideal_wps_global"]
    rows = []
    base = None
    for n in (8, 16, 32, 64, 128, 256, 512, 1024, 2048):
        r = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(n, zero_stage=2),
                         2 * n, 4096)
        if base is None:
            base = r
        rows.append([n, round(r.wps_per_device), round(r.wps),
                     round(r.tflops_per_device, 1), round(r.mfu, 4),
                     round(r.t_comm_exposed * 1e3, 1),
                     round(r.power_per_device, 1),
                     round(r.tokens_per_joule, 2),
                     round(base.wps_per_device * n)])
    return header, rows


def fig4_collective_time():
    header = ["gpus", "ag_ms_per_layer", "rs_ms_per_layer"]
    layer_bytes = LLAMA2_7B.param_count() / LLAMA2_7B.n_layers * 2
    rows = []
    for n in (8, 32, 128, 512, 2048):
        rows.append([n,
                     round(cm.t_all_gather(cm.H100, layer_bytes, n) * 1e3, 2),
                     round(cm.t_reduce_scatter(cm.H100, layer_bytes * 2, n) * 1e3, 2)])
    return header, rows


def fig5_strong_scaling():
    header = ["nodes", "gpus", "best_spec", "best_tp", "best_pp", "best_cp",
              "mfu", "wps_global", "wps_per_dev", "power_W", "tokens_per_J"]
    rows = []
    for nodes in (2, 4, 8, 16, 32):
        n = nodes * 8
        p = _search(LLAMA2_7B, cm.H100, n, 32, 4096)[0]
        b, s = p.report, p.strategy
        rows.append([nodes, n, p.spec, s.tp, s.pp, s.cp, round(b.mfu, 4),
                     round(b.wps), round(b.wps_per_device),
                     round(b.power_per_device, 1),
                     round(b.tokens_per_joule, 2)])
    return header, rows


def fig6_parallelism_sweep():
    header = ["spec", "tp", "pp", "cp", "dp", "wps_global", "mfu",
              "exposed_ms", "power_W", "fits_80GB"]
    rows = []
    for p in _search(LLAMA2_7B, cm.H100, 256, 512, 4096):
        r, s = p.report, p.strategy
        rows.append([p.spec, s.tp, s.pp, s.cp, r.strategy.dp, round(r.wps),
                     round(r.mfu, 4), round(r.t_comm_exposed * 1e3, 1),
                     round(r.power_per_device, 1), int(r.fits)])
    return header, rows


def fig7_hw_generations():
    header = ["hw", "tp", "pp", "wps_global", "mfu", "exposed_frac"]
    rows = []
    for hw in (cm.V100, cm.A100, cm.H100):
        for p in _search(LLAMA2_7B, hw, 256, 512, 4096, tps=(1, 2, 4, 8),
                         pps=(1, 2, 4), cps=(1,)):
            r, s = p.report, p.strategy
            rows.append([hw.name, s.tp, s.pp, round(r.wps), round(r.mfu, 4),
                         round(r.t_comm_exposed / r.t_step, 4)])
    return header, rows


def fig8_model_size():
    header = ["model", "params_B", "best_spec", "best_tp", "best_pp", "mfu",
              "exposed_frac", "wps_global"]
    rows = []
    for m in (LLAMA2_1B, LLAMA2_7B, LLAMA2_13B, LLAMA2_70B):
        p = _search(m, cm.H100, 256, 512, 4096)[0]
        b = p.report
        rows.append([m.name, round(m.param_count() / 1e9, 2), p.spec,
                     p.strategy.tp, p.strategy.pp, round(b.mfu, 4),
                     round(b.t_comm_exposed / b.t_step, 4), round(b.wps)])
    return header, rows


def fig9_context_length():
    header = ["seq_len", "mfu", "exposed_frac", "power_W", "tokens_per_J"]
    rows = []
    for seq in (1024, 2048, 4096, 8192, 16384):
        r = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(512, zero_stage=2),
                         1024, seq)
        rows.append([seq, round(r.mfu, 4),
                     round(r.t_comm_exposed / r.t_step, 4),
                     round(r.power_per_device, 1),
                     round(r.tokens_per_joule, 2)])
    return header, rows


def fig11_pretrain_scale():
    header = ["model", "gpus", "best_spec", "best_tp", "mfu", "wps_per_dev"]
    rows = []
    for m, gb in ((LLAMA2_7B, 2048), (LLAMA2_70B, 1024)):
        for n in (512, 1024, 2048):
            p = _search(m, cm.H100, n, gb, 4096)[0]
            rows.append([m.name, n, p.spec, p.strategy.tp,
                         round(p.report.mfu, 4),
                         round(p.report.wps_per_device)])
    return header, rows


def fig12_context_parallel():
    """TP vs CP at equal model-axis degree, priced from the same descriptor
    the SPMD lowering uses (spec strings, not hand-built cost strategies)."""
    header = ["spec", "mode", "degree", "wps_global", "mfu"]
    topo = _topo(cm.H100, 256)
    shape = ShapeConfig("fig12", 4096, 512, "train")
    rows = []
    for deg in (2, 4, 8):
        for spec in (f"fsdp_tp{deg}_z2", f"fsdp_cp{deg}_z2"):
            s = strategy_lib.parse(spec)
            r = strategy_lib.evaluate(LLAMA2_7B, s, topo, shape)
            rows.append([spec, "cp" if s.cp > 1 else "tp", deg,
                         round(r.wps), round(r.mfu, 4)])
    return header, rows


def fig13_pareto():
    """Planner value-add: throughput x energy Pareto front at 256 GPUs."""
    header = ["spec", "wps_global", "tokens_per_J", "mfu", "on_front"]
    ranked = _search(LLAMA2_7B, cm.H100, 256, 512, 4096)
    front = {p.spec for p in strategy_lib.pareto_front(
        ranked, objectives=("wps", "tokens_per_joule"))}
    rows = []
    for p in ranked:
        rows.append([p.spec, round(p.report.wps),
                     round(p.report.tokens_per_joule, 2),
                     round(p.report.mfu, 4), int(p.spec in front)])
    return header, rows


def fig14_memory():
    header = ["dp_gpus", "zero_stage", "mem_GB_per_dev"]
    rows = []
    for n in (8, 16, 32, 64, 128, 256):
        for stage in (0, 3):
            r = cm.step_time(LLAMA2_7B, cm.H100,
                             cm.Strategy(n, zero_stage=stage), 2 * n, 4096)
            rows.append([n, stage, round(r.memory_per_device / 2**30, 2)])
    return header, rows


def fig1_power():
    header = ["gpus", "power_W_per_dev", "tokens_per_J", "ideal_tokens_per_J"]
    rows = []
    base = None
    for n in (8, 32, 128, 512, 2048):
        r = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(n, zero_stage=2),
                         2 * n, 4096)
        if base is None:
            base = r
        rows.append([n, round(r.power_per_device, 1),
                     round(r.tokens_per_joule, 2),
                     round(base.tokens_per_joule, 2)])
    return header, rows


def tpu_v5e_transfer():
    """The paper's strategy sweep on the TPU v5e production mesh (DESIGN §2):
    the island boundary moves from the 8-GPU node to the 256-chip pod.
    Specs lower on the actual pod topology, so multi-pod rows charge the
    HSDP cross-pod gradient all-reduce the (16,16)-era sweep ignored."""
    header = ["chips", "spec", "wps_global", "mfu", "exposed_frac"]
    rows = []
    for pods in (1, 2):
        topo = strategy_lib.pod_topology(pods=pods)
        shape = ShapeConfig("tpu", 4096, 256, "train")
        for tp in (1, 4, 16):
            spec = f"hsdp_tp{tp}" if tp > 1 else "hsdp"
            s = strategy_lib.parse(spec)
            r = strategy_lib.evaluate(LLAMA2_7B, s, topo, shape)
            rows.append([topo.n_devices, spec, round(r.wps), round(r.mfu, 4),
                         round(r.t_comm_exposed / r.t_step, 4)])
    return header, rows


ALL = {
    "fig1_power": fig1_power,
    "fig2_collectives": fig2_collectives,
    "fig3_weak_scaling": fig3_weak_scaling,
    "fig4_collective_time": fig4_collective_time,
    "fig5_strong_scaling": fig5_strong_scaling,
    "fig6_parallelism_sweep": fig6_parallelism_sweep,
    "fig7_hw_generations": fig7_hw_generations,
    "fig8_model_size": fig8_model_size,
    "fig9_context_length": fig9_context_length,
    "fig11_pretrain_scale": fig11_pretrain_scale,
    "fig12_context_parallel": fig12_context_parallel,
    "fig13_pareto": fig13_pareto,
    "fig14_memory": fig14_memory,
    "tpu_v5e_transfer": tpu_v5e_transfer,
}
