"""Batched serving example (deliverable b): prefill a batch of prompts,
then decode with the KV/state cache — on a hybrid (Jamba-family) model to
exercise attention + Mamba + MoE caches together.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import Runtime, init_params
from repro.serve import ServeEngine


def main():
    cfg = reduced(get_config("jamba-v0.1-52b"))
    rt = Runtime(rwkv_chunk=16, mamba_chunk=16, moe_impl="dense")
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)

    batch, prompt_len, n_new = 8, 48, 24
    engine = ServeEngine(cfg, params, rt, max_len=prompt_len + n_new)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    greedy = engine.generate(prompts, n_new)
    t1 = time.time()
    sampled = engine.generate(prompts, n_new, temperature=0.8, key=key)
    t2 = time.time()

    assert greedy.shape == (batch, prompt_len + n_new)
    # greedy decode is deterministic
    again = engine.generate(prompts, n_new)
    assert bool(jnp.all(again == greedy))
    print(f"greedy:  {batch * n_new} tokens in {t1-t0:.2f}s")
    print(f"sampled: {batch * n_new} tokens in {t2-t1:.2f}s")
    print("batch 0 greedy tail:", greedy[0, -8:].tolist())
    print("batch 0 sampled tail:", sampled[0, -8:].tolist())
    print("serve_batched OK")


if __name__ == "__main__":
    main()
