"""End-to-end driver (deliverable b): train a ~100M-parameter llama-family
model for a few hundred steps on the synthetic corpus, with checkpointing
and the full sharded train loop.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

On this CPU container the model runs on a 1-device mesh; on a TPU slice the
identical script uses every chip (the plan/runtime adapt to the mesh).
"""
import argparse

import jax.numpy as jnp

from repro import strategy as strategy_lib
from repro.configs import ShapeConfig
from repro.configs.base import ModelConfig
from repro.core import parallel as par
from repro.data import Batcher, SyntheticSource
from repro.optim import AdamWConfig
from repro.train.trainer import TrainConfig, train_loop

# ~100M params: 12L, d=768, vocab 16k (llama-style SwiGLU decoder)
M100 = ModelConfig(
    name="llama-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=2048, vocab_size=16384,
    source="paper-style Llama-2 family scaled to ~100M")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq_len", type=int, default=256)
    ap.add_argument("--global_batch", type=int, default=8)
    ap.add_argument("--ckpt_every", type=int, default=100)
    args = ap.parse_args()

    cfg = M100
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    shape = ShapeConfig("e2e", args.seq_len, args.global_batch, "train")
    topo = strategy_lib.host_topology()
    plan = strategy_lib.Strategy(dp_mode="fsdp").to_plan(cfg, topo, shape)
    rt = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                          compute_dtype=jnp.float32, remat=False)

    batches = Batcher(SyntheticSource(cfg.vocab_size, seed=1),
                      shape.seq_len, shape.global_batch)
    tc = TrainConfig(steps=args.steps, warmup=20, log_every=20,
                     ckpt_every=args.ckpt_every,
                     ckpt_dir="results/ckpt/llama-100m",
                     opt=AdamWConfig(lr=6e-4))
    params, _, history = train_loop(cfg, plan, rt, tc, batches)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f}")
    assert last < first - 0.5, "expected substantial learning on synthetic data"


if __name__ == "__main__":
    main()
