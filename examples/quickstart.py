"""Quickstart: train a tiny Qwen3-family model on synthetic data, then
generate from it — the whole public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro import strategy as strategy_lib
from repro.configs import ShapeConfig, get_config, reduced
from repro.core import parallel as par
from repro.data import Batcher, SyntheticSource
from repro.optim import AdamWConfig
from repro.serve import ServeEngine
from repro.train.trainer import TrainConfig, train_loop


def main():
    cfg = reduced(get_config("qwen3-0.6b"))          # 2 layers, d_model 256
    shape = ShapeConfig("quickstart", seq_len=128, global_batch=8, mode="train")
    topo = strategy_lib.host_topology()
    plan = strategy_lib.Strategy(dp_mode="fsdp").to_plan(cfg, topo, shape)
    rt = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                          compute_dtype=jnp.float32, remat=False)

    batches = Batcher(SyntheticSource(cfg.vocab_size, seed=0),
                      shape.seq_len, shape.global_batch)
    tc = TrainConfig(steps=60, warmup=5, log_every=10,
                     opt=AdamWConfig(lr=1e-3))
    params, _, history = train_loop(cfg, plan, rt, tc, batches)
    assert history[-1]["loss"] < history[0]["loss"], "did not learn"

    engine = ServeEngine(cfg, params, rt, max_len=160)
    prompts = jnp.asarray(next(iter(batches))["tokens"][:2, :64])
    out = engine.generate(prompts, n_new=16)
    print("generated:", out[0, -16:].tolist())
    print(f"quickstart OK: loss {history[0]['loss']:.3f} -> "
          f"{history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
