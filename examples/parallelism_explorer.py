"""The paper's §5 recommendations as a tool: given a model, a cluster, and
a batch, search the parallelization-strategy space with the calibrated cost
model and print the ranked configurations.

    PYTHONPATH=src python examples/parallelism_explorer.py \
        --model llama2-7b --hw H100 --gpus 256 --global_batch 512
"""
import argparse

from repro.configs import get_config
from repro.core import costmodel as cm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama2-7b")
    ap.add_argument("--hw", default="H100", choices=sorted(cm.HARDWARE))
    ap.add_argument("--gpus", type=int, default=256)
    ap.add_argument("--global_batch", type=int, default=512)
    ap.add_argument("--seq_len", type=int, default=4096)
    ap.add_argument("--zero", type=int, default=2, choices=[0, 2, 3])
    ap.add_argument("--hbm_gb", type=float, default=80.0)
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.model)
    hw = cm.HARDWARE[args.hw]
    reports = cm.sweep_strategies(cfg, hw, args.gpus, args.global_batch,
                                  args.seq_len, zero_stage=args.zero,
                                  hbm_capacity=args.hbm_gb * 2**30)
    reports.sort(key=lambda r: -r.wps)
    print(f"{cfg.name} on {args.gpus}x {hw.name}, gb={args.global_batch}, "
          f"seq={args.seq_len}, ZeRO-{args.zero}")
    print(f"{'tp':>3} {'pp':>3} {'dp':>5} {'WPS':>12} {'MFU':>6} "
          f"{'exposed':>8} {'W/gpu':>6} {'tok/J':>7} {'mem GB':>7} fits")
    for r in reports[: args.top]:
        s = r.strategy
        print(f"{s.tp:>3} {s.pp:>3} {s.dp:>5} {r.wps:>12,.0f} {r.mfu:>6.3f} "
              f"{r.t_comm_exposed / r.t_step:>8.1%} {r.power_per_device:>6.0f} "
              f"{r.tokens_per_joule:>7.2f} {r.memory_per_device/2**30:>7.1f} "
              f"{'y' if r.fits else 'n'}")
    best = reports[0]
    print(f"\nrecommendation: tp={best.strategy.tp} pp={best.strategy.pp} "
          f"dp={best.strategy.dp}  (paper §5: at scale, small model-parallel "
          f"degrees beat pure FSDP)")


if __name__ == "__main__":
    main()
