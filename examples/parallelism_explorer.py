"""The paper's §5 recommendations as a tool: given a model, a cluster, and
a batch, search the executable-strategy space with the cost-model-driven
planner (repro.strategy) and print the ranked configurations — including
context-parallel degrees and the throughput x energy Pareto front.

    PYTHONPATH=src python examples/parallelism_explorer.py \
        --model llama2-7b --hw H100 --gpus 256 --global_batch 512
"""
import argparse

from repro import strategy as strategy_lib
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import costmodel as cm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama2-7b")
    ap.add_argument("--hw", default="H100", choices=sorted(cm.HARDWARE))
    ap.add_argument("--gpus", type=int, default=256)
    ap.add_argument("--global_batch", type=int, default=512)
    ap.add_argument("--seq_len", type=int, default=4096)
    ap.add_argument("--zero", type=int, default=2, choices=[0, 2, 3])
    ap.add_argument("--hbm_gb", type=float, default=80.0)
    ap.add_argument("--objective", default="wps",
                    choices=sorted(strategy_lib.OBJECTIVES))
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.model)
    hw = cm.HARDWARE[args.hw]
    topo = strategy_lib.Topology(hw.name, args.gpus, island=hw.island,
                                 hardware=hw.name, hbm=args.hbm_gb * 2**30)
    shape = ShapeConfig("explore", args.seq_len, args.global_batch, "train")
    dp_mode = "ddp" if args.zero == 0 else "fsdp"
    ranked = strategy_lib.search(
        cfg, topo, shape, objective=args.objective, dp_modes=(dp_mode,),
        zero_stages=(args.zero,), pps=(1, 2, 4, 8, 16), cps=(1, 2, 4, 8),
        require_fits=False, require_lowerable=False)
    front = {p.spec for p in strategy_lib.pareto_front(
        ranked, objectives=("wps", "tokens_per_joule"))}

    print(f"{cfg.name} on {args.gpus}x {hw.name}, gb={args.global_batch}, "
          f"seq={args.seq_len}, ZeRO-{args.zero}, objective={args.objective}")
    print(f"{'spec':>18} {'tp':>3} {'pp':>3} {'cp':>3} {'ep':>3} {'dp':>5} "
          f"{'WPS':>12} "
          f"{'MFU':>6} {'exposed':>8} {'W/gpu':>6} {'tok/J':>7} "
          f"{'mem GB':>7} fits runs pareto")
    for p in ranked[: args.top]:
        r, s = p.report, p.strategy
        print(f"{p.spec:>18} {s.tp:>3} {s.pp:>3} {s.cp:>3} {s.ep:>3} "
              f"{r.strategy.dp:>5} {r.wps:>12,.0f} {r.mfu:>6.3f} "
              f"{r.t_comm_exposed / r.t_step:>8.1%} "
              f"{r.power_per_device:>6.0f} {r.tokens_per_joule:>7.2f} "
              f"{r.memory_per_device / 2**30:>7.1f} "
              f"{'y' if r.fits else 'n':>4} {'y' if p.lowers else 'n':>4} "
              f"{'*' if p.spec in front else '':>6}")
    # recommend only specs the SPMD lowering can execute (pp>1 lowers
    # through the GPipe pipe axis now; a point may still fail to lower
    # e.g. when the layer stack is not uniform or degrees do not divide)
    best = next((p for p in ranked if p.lowers), None)
    if best is None:
        print("\nno ranked strategy lowers on this topology "
              "(analytic-only table)")
    else:
        print(f"\nrecommendation: --strategy {best.spec}  (paper §5: at "
              f"scale, small model-parallel degrees beat pure FSDP; the "
              f"same spec string drives repro.launch.train / dryrun / serve)")


if __name__ == "__main__":
    main()
