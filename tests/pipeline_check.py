"""Pipeline-parallel equivalence check (subprocess, 4 fake devices):
GPipe-scheduled layers over a 'pipe' axis == sequential application,
forward AND gradient."""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4").strip()

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.parallel import use_mesh
from repro.core.pipeline import bubble_fraction, make_pipelined_block_fn, pipeline_apply
from repro.models.layers import Runtime
from repro.models.transformer import _apply_layer, _init_layer, _sig, _tree_stack


def main():
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=4, d_model=128)
    rt = Runtime()
    key = jax.random.PRNGKey(0)
    layers = [_init_layer(cfg, i, k) for i, k in
              enumerate(jax.random.split(key, 4))]

    mesh = jax.make_mesh((4,), ("pipe",))
    P_stages, M, mb, S, d = 4, 8, 2, 16, cfg.d_model
    x = jax.random.normal(key, (M, mb, S, d)) * 0.5

    # stacked: (P, layers_per_stage=1, ...)
    stacked = {"layers": _tree_stack([_tree_stack([l]) for l in layers])}
    stage_fn = make_pipelined_block_fn(cfg, rt)

    def pipelined(params, x):
        return pipeline_apply(stage_fn, params, x, mesh, "pipe")

    def sequential(layers, x):
        h = x.reshape(M * mb, S, d)
        for lp in layers:
            h, _, _ = _apply_layer(cfg, _sig(cfg, 0), lp, h, None, rt)
        return h.reshape(M, mb, S, d)

    with use_mesh(mesh):
        out_p = jax.jit(pipelined)(stacked, x)
    out_s = sequential(layers, x)
    err = float(jnp.max(jnp.abs(out_p - out_s)))
    print(f"pipeline fwd err {err:.2e}")
    assert err < 1e-4, err

    # gradient path through shard_map + ppermute
    def loss_p(params):
        return jnp.sum(pipelined(params, x) ** 2)

    def loss_s(layers):
        return jnp.sum(sequential(layers, x) ** 2)

    with use_mesh(mesh):
        g_p = jax.jit(jax.grad(loss_p))(stacked)
    g_s = jax.grad(loss_s)(layers)
    g_s_stacked = {"layers": _tree_stack([_tree_stack([l]) for l in g_s])}
    errs = [float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(g_p), jax.tree.leaves(g_s_stacked))]
    print(f"pipeline grad err {max(errs):.2e}")
    assert max(errs) < 5e-3, max(errs)

    assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-9
    print("PIPELINE checks passed")


if __name__ == "__main__":
    main()
