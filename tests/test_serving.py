"""Serving subsystem: paged KV cache, flash-decode kernel, scheduler,
and end-to-end paged-vs-dense engine equivalence.

The load-bearing invariant: the paged continuous-batching engine is a
*memory-layout and scheduling* change, not a numerical one — greedy
decode must produce bit-identical token ids to the dense-cache engine
across block sizes, ragged prompt lengths, and oversubscribed slot
counts, and sampled decode must reproduce exactly under the engine's
(stream, position) key derivation.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.kernels.ops import paged_decode_attention
from repro.kernels.ref import paged_attention_ref
from repro.models import transformer as tfm
from repro.models.layers import Runtime
from repro.serve import BlockAllocator, PagedCacheError, ServeEngine

RT = Runtime()


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("qwen3-0.6b"))
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, key, batch, length):
    return jax.random.randint(key, (batch, length), 0, cfg.vocab_size)


# ---------------------------------------------------------------------------
# block allocator
# ---------------------------------------------------------------------------

def test_allocator_all_or_nothing_and_refcounts():
    a = BlockAllocator(8, 16)
    assert a.n_free == 8
    got = a.allocate(5)
    assert got is not None and len(got) == 5 and a.n_free == 3
    assert a.allocate(4) is None          # short pools allocate nothing
    assert a.n_free == 3
    shared = a.fork(got[:2])              # refcount++, same ids
    assert shared == got[:2] and a.n_free == 3
    a.free(got)                           # forked blocks survive the free
    assert a.n_free == 6
    a.free(shared)
    assert a.n_free == 8
    with pytest.raises(PagedCacheError):
        a.free(shared)                    # double free


def test_allocator_copy_on_write():
    a = BlockAllocator(4, 8)
    blocks = a.allocate(1)
    shared = a.fork(blocks)
    new = a.copy_on_write(shared[0])
    assert new != blocks[0]               # shared -> fresh block
    a.free(blocks)
    sole = a.allocate(1)
    assert a.copy_on_write(sole[0]) == sole[0]   # exclusive -> in place


# ---------------------------------------------------------------------------
# flash-decode kernel vs jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("heads,kv_heads", [(4, 4), (4, 2), (8, 1)])
def test_flash_decode_matches_oracle(heads, kv_heads):
    key = jax.random.PRNGKey(0)
    B, D, bs, P, nb = 3, 16, 8, 32, 6
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, 1, heads, D))
    k_pool = jax.random.normal(ks[1], (P, bs, kv_heads, D))
    v_pool = jax.random.normal(ks[2], (P, bs, kv_heads, D))
    # ragged contexts, distinct pool blocks per request, tail unallocated
    ctx = jnp.asarray([5, bs * 3, bs * nb], jnp.int32)
    perm = jax.random.permutation(ks[3], P)[:B * nb].reshape(B, nb)
    nalloc = -(-ctx // bs)
    tbl = jnp.where(jnp.arange(nb)[None] < nalloc[:, None], perm, -1)

    ref = paged_attention_ref(q, k_pool, v_pool, tbl, ctx)
    for n_splits in (1, 2, 4):
        out = paged_decode_attention(q, k_pool, v_pool, tbl, ctx,
                                     n_splits=n_splits)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, (n_splits, err)


def test_paged_ref_layout_invariance():
    """The paged oracle depends only on the *logical* sequence: permuting
    the physical pool blocks (with the table updated to match) changes
    nothing — the property that makes block reuse sound."""
    key = jax.random.PRNGKey(3)
    B, S, H, Kv, D, bs = 2, 24, 4, 2, 16, 8
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, S, Kv, D))
    v = jax.random.normal(ks[2], (B, S, Kv, D))
    nb = S // bs
    k_pool = k.reshape(B * nb, bs, Kv, D)
    v_pool = v.reshape(B * nb, bs, Kv, D)
    tbl = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    ctx = jnp.full((B,), S, jnp.int32)
    paged = paged_attention_ref(q, k_pool, v_pool, tbl, ctx)
    perm = jax.random.permutation(ks[3], B * nb)
    inv = jnp.argsort(perm)
    paged2 = paged_attention_ref(q, k_pool[inv], v_pool[inv],
                                 perm[tbl.reshape(-1)].reshape(B, nb), ctx)
    assert float(jnp.max(jnp.abs(paged - paged2))) < 1e-6


# ---------------------------------------------------------------------------
# engine equivalence: paged continuous batching vs dense static batch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_size,prefill_chunk", [(8, 8), (16, 4)])
def test_paged_greedy_bitmatches_dense(small_model, block_size,
                                       prefill_chunk):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, RT, max_len=96, n_slots=4,
                      block_size=block_size, prefill_chunk=prefill_chunk,
                      steps_per_tick=3)
    assert eng.paged_ok
    prompts = _prompts(cfg, jax.random.PRNGKey(1), 4, 13)
    out_p = np.asarray(eng.generate(prompts, 10))
    out_s = np.asarray(eng.generate_static(prompts, 10))
    assert np.array_equal(out_p, out_s)


def test_paged_ragged_oversubscribed_matches_dense(small_model):
    """More requests than slots, ragged prompt lengths: every request's
    greedy continuation must bit-match a dense-cache run of that prompt
    alone — continuous batching must not leak state across slots."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, RT, max_len=64, n_slots=2, block_size=8,
                      prefill_chunk=8, steps_per_tick=4, n_blocks=18)
    lens = [3, 17, 9, 25, 1]
    n_new = 6
    rids = []
    for i, L in enumerate(lens):
        p = np.asarray(_prompts(cfg, jax.random.PRNGKey(10 + i), 1, L)[0])
        rids.append((eng.submit(p, n_new), p))
    done = eng.run_until_drained(key=jax.random.PRNGKey(3))
    for rid, p in rids:
        ref = np.asarray(
            eng.generate_static(jnp.asarray(p)[None], n_new))[0, len(p):]
        assert np.array_equal(done[rid], ref), (rid, len(p))
    # completed requests freed every block
    assert eng._sched.alloc.n_free == 18
    assert not eng._sched.running and not eng._sched.waiting


def test_paged_sampled_reproducible_and_batch_invariant(small_model):
    """Sampling keys are (stream, position): the same explicit key yields
    identical tokens across calls, and a request's tokens do not depend
    on what else shares the batch."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, RT, max_len=64, n_slots=4, block_size=8,
                      prefill_chunk=8, steps_per_tick=4)
    key = jax.random.PRNGKey(11)
    prompts = _prompts(cfg, jax.random.PRNGKey(4), 3, 9)
    a = np.asarray(eng.generate(prompts, 8, temperature=0.9, key=key))
    b = np.asarray(eng.generate(prompts, 8, temperature=0.9, key=key))
    assert np.array_equal(a, b)
    # batch invariance: row 0 alone, same stream id and key
    rid = eng.submit(np.asarray(prompts[0]), 8, temperature=0.9, stream=0)
    solo = eng.run_until_drained(key=key)[rid]
    assert np.array_equal(solo, a[0, 9:])


def test_generate_seed_advances_between_calls(small_model):
    """The seed engine reused PRNGKey(0) on every generate() call; now
    repeated sampled calls draw fresh tokens unless a key is pinned."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, RT, max_len=48, n_slots=2, block_size=8)
    prompts = _prompts(cfg, jax.random.PRNGKey(5), 2, 7)
    c = np.asarray(eng.generate(prompts, 8, temperature=1.0))
    d = np.asarray(eng.generate(prompts, 8, temperature=1.0))
    assert not np.array_equal(c, d)
    # static path too
    e = np.asarray(eng.generate_static(prompts, 8, temperature=1.0))
    f = np.asarray(eng.generate_static(prompts, 8, temperature=1.0))
    assert not np.array_equal(e, f)


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------

def _mk_sched(n_slots=2, n_blocks=16, block_size=8, chunk=8):
    from repro.serve import Scheduler
    return Scheduler(n_slots, BlockAllocator(n_blocks, block_size),
                     prefill_chunk=chunk, steps_per_tick=4)


def test_scheduler_fifo_no_starvation():
    """Head-of-line blocking: a big request at the head admits before any
    smaller request behind it, even when the small one would fit now."""
    s = _mk_sched(n_slots=2, n_blocks=10)
    big = s.submit(np.zeros(40, np.int32), 8)       # needs 7 blocks
    small = s.submit(np.zeros(4, np.int32), 4)      # needs 2 blocks
    tiny = s.submit(np.zeros(2, np.int32), 2)
    first = s.admit()
    assert [r.rid for r in first] == [big, small]   # FIFO, both fit
    assert s.alloc.n_free == 1
    assert not s.admit()                            # tiny blocked on blocks
    # completing the big request unblocks the queue head
    req = s.running[[k for k, r in s.running.items() if r.rid == big][0]]
    req.generated = list(range(req.n_new))
    req.prefilled = req.prompt_len
    s.complete(req)
    assert [r.rid for r in s.admit()] == [tiny]


def test_scheduler_completion_frees_blocks_and_slot():
    s = _mk_sched(n_slots=1, n_blocks=8)
    r1 = s.submit(np.zeros(8, np.int32), 3)
    (req,) = s.admit()
    free_before = s.alloc.n_free
    req.prefilled = req.prompt_len
    req.generated = [1, 2, 3]
    assert req.remaining == 0
    s.complete(req)
    # full footprint returned: blocks_for(8 prompt + 3 new + 1) = 2
    assert free_before == 6 and s.alloc.n_free == 8
    assert req.slot == -1 and req.done and s.finished[r1] is req
    # slot reusable immediately
    s.submit(np.zeros(8, np.int32), 3)
    assert len(s.admit()) == 1


def test_scheduler_prefill_oldest_first():
    s = _mk_sched(n_slots=2, n_blocks=32, chunk=4)
    a = s.submit(np.zeros(10, np.int32), 2)
    b = s.submit(np.zeros(10, np.int32), 2)
    s.admit()
    # chunked prefill always feeds the oldest unfinished prompt
    for _ in range(3):                   # 10-token prompt: chunks 4+4+2
        req = s.next_prefill()
        assert req.rid == a
        req.prefilled += min(4, req.prompt_len - req.prefilled)
    assert s.next_prefill().rid == b     # a done -> oldest unfinished is b
    assert [r.rid for r in s.decode_slots()] == [a]


# ---------------------------------------------------------------------------
# request TTL + cancellation (resilience satellite)
# ---------------------------------------------------------------------------

def _mk_timed_sched(clock, n_slots=2, n_blocks=16):
    from repro.serve import Scheduler
    return Scheduler(n_slots, BlockAllocator(n_blocks, 8),
                     prefill_chunk=8, steps_per_tick=4, clock=clock)


def test_scheduler_ttl_expires_running_and_waiting():
    """A passed deadline retires the request wherever it is: a running one
    frees blocks+slot like completion, a waiting one stops blocking the
    queue; both keep partial state and record finish_reason='timeout'."""
    now = [0.0]
    s = _mk_timed_sched(lambda: now[0], n_slots=1, n_blocks=8)
    r1 = s.submit(np.zeros(8, np.int32), 3, ttl_s=5.0)   # will run
    r2 = s.submit(np.zeros(8, np.int32), 3, ttl_s=2.0)   # stuck waiting
    r3 = s.submit(np.zeros(8, np.int32), 3)              # no TTL
    (req1,) = s.admit()
    req1.prefilled = req1.prompt_len
    req1.generated = [7]                                 # partial output
    assert s.expire() == []                              # nothing due yet
    now[0] = 3.0                                         # r2's deadline only
    expired = s.expire()
    assert [(slot, r.rid) for slot, r in expired] == [(-1, r2)]
    assert s.finished[r2].finish_reason == "timeout"
    assert [r.rid for r in s.waiting] == [r3]            # head unblocked
    now[0] = 6.0                                         # r1's deadline
    (slot, req) = s.expire()[0]
    assert (slot, req.rid) == (0, r1)
    assert req.finish_reason == "timeout" and req.slot == -1
    assert req.generated == [7]                          # partial kept
    assert s.alloc.n_free == 8                           # blocks returned
    assert [r.rid for r in s.admit()] == [r3]            # seat reusable


def test_scheduler_cancel_waiting_running_and_unknown():
    now = [0.0]
    s = _mk_timed_sched(lambda: now[0], n_slots=1, n_blocks=8)
    r1 = s.submit(np.zeros(8, np.int32), 3)
    r2 = s.submit(np.zeros(8, np.int32), 3)
    s.admit()
    slot, req = s.cancel(r1)                             # running
    assert slot == 0 and req.finish_reason == "cancelled"
    assert s.alloc.n_free == 8 and not s.running
    assert s.cancel(r2) == (-1, s.finished[r2])          # waiting
    assert s.finished[r2].finish_reason == "cancelled"
    assert s.cancel(r1) is None                          # already finished
    assert s.cancel(999) is None                         # unknown rid


def test_engine_ttl_and_cancel_free_seats_and_drain(small_model):
    """End-to-end: an immediately-expiring request and a cancelled one
    must not wedge run_until_drained or leak blocks; survivors complete
    with full budgets and 'length' finish reason."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, RT, max_len=64, n_slots=2, block_size=8,
                      prefill_chunk=8, steps_per_tick=4)
    p = np.asarray(_prompts(cfg, jax.random.PRNGKey(21), 3, 9))
    ok = eng.submit(p[0], 5)
    doomed = eng.submit(p[1], 5, ttl_s=1e-9)             # expires first tick
    gone = eng.submit(p[2], 5)
    assert eng.cancel(gone)
    assert not eng.cancel(gone)                          # second time: no-op
    assert not eng.cancel(12345)
    sched = eng._sched
    out = eng.run_until_drained(key=jax.random.PRNGKey(3))
    assert len(out[ok]) == 5
    assert len(out[doomed]) < 5                          # retired early
    assert sched.alloc.n_free == eng.n_blocks            # nothing leaked
    assert not sched.running and not sched.waiting


# ---------------------------------------------------------------------------
# planner decode mode (satellite)
# ---------------------------------------------------------------------------

def test_planner_decode_mode_latency_objective():
    from repro import strategy as sl
    from repro.configs import ShapeConfig
    cfg = get_config("llama2-7b")
    topo = sl.get_topology("pod")
    shape = ShapeConfig("d", 4096, 16, "decode")
    ranked = sl.search(cfg, topo, shape, top=8)
    assert ranked
    best = ranked[0].report
    assert best.latency_p50 > 0 and best.latency_p99 >= best.latency_p50
    # ranked by ascending p50
    p50s = [p.report.latency_p50 for p in ranked]
    assert p50s == sorted(p50s)
    # train shapes keep the throughput default and carry no latency
    tshape = ShapeConfig("t", 4096, 64, "train")
    rt_ = sl.search(cfg, topo, tshape, top=1)
    assert rt_[0].report.latency_p50 == 0.0
    assert rt_[0].score == rt_[0].report.wps
    assert sl.default_objective(shape) == "p50_latency"
    assert sl.default_objective(tshape) == "wps"
