"""Multi-device SPMD equivalence (promoted from the ad-hoc
tests/spmd_check.py subprocess script): the sharded train step
(FSDP x TP / context-parallel plans on a (2, 4) mesh) produces the same
loss/gradients as the single-device step, and a sharded decode step
matches the unsharded one — in-process on the shared 8-virtual-device
configuration from conftest."""
import jax
import jax.numpy as jnp
import pytest

from repro import strategy as strategy_lib
from repro.configs import ShapeConfig, get_config, reduced
from repro.core import parallel as par
from repro.launch.specs import concrete_train_batch
from repro.models import transformer as tfm
from repro.models.layers import Runtime
from repro.optim import init_opt_state
from repro.train.trainer import (TrainConfig, make_train_step,
                                 place_train_state)

TOL = 5e-3


def _plan(cfg, shape, attn_override=None):
    """(2, 4) data x model plan over the host devices, via the unified
    Strategy API (the deprecated choose_plan shim is no longer used)."""
    s = strategy_lib.Strategy(dp_mode="fsdp", tp=4, attn=attn_override)
    return s.to_plan(cfg, strategy_lib.host_topology(), shape)


def _check_train(arch: str, attn_override=None):
    cfg = reduced(get_config(arch), d_model=256)
    shape = ShapeConfig("t", 64, 4, "train")
    plan = _plan(cfg, shape, attn_override)
    mesh = plan.mesh
    rt_single = Runtime(rwkv_chunk=8, mamba_chunk=8, moe_impl="dropping",
                        moe_groups=1, attn_min_chunked_len=32,
                        attn_q_chunk=16, attn_kv_chunk=16)
    rt_shard = par.make_runtime(
        cfg, plan, shape, param_dtype=jnp.float32, compute_dtype=jnp.float32,
        remat=False, rwkv_chunk=8, mamba_chunk=8,
        attn_min_chunked_len=32, attn_q_chunk=64 if plan.attn == "context" else 16,
        attn_kv_chunk=16, moe_impl="dropping")

    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = concrete_train_batch(cfg, shape.global_batch, shape.seq_len, key)
    tc = TrainConfig()

    # single device
    p1, o1, m1 = make_train_step(cfg, rt_single, tc)(
        params, init_opt_state(params), batch)

    # sharded
    with par.use_mesh(mesh):
        params_s, opt_s, batch_s, pshard, _ = place_train_state(
            cfg, plan, params, init_opt_state(params), batch)
        step = jax.jit(make_train_step(cfg, rt_shard, tc),
                       out_shardings=(pshard, None, None))
        p2, o2, m2 = step(params_s, opt_s, batch_s)

    dl = abs(float(m1["loss"]) - float(m2["loss"]))
    dg = abs(float(m1["grad_norm"]) - float(m2["grad_norm"]))
    rel_g = dg / max(float(m1["grad_norm"]), 1e-6)
    # updated params agree
    dp = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert dl < TOL, (arch, dl)
    assert rel_g < TOL, (arch, rel_g)
    assert dp < 5e-2, (arch, dp)


def _check_decode(arch: str):
    cfg = reduced(get_config(arch), d_model=256)
    shape = ShapeConfig("d", 64, 4, "decode")
    plan = _plan(cfg, shape)
    mesh = plan.mesh
    rt0 = Runtime(rwkv_chunk=8, mamba_chunk=8, moe_impl="dense")
    rt_s = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                            compute_dtype=jnp.float32, remat=False,
                            rwkv_chunk=8, mamba_chunk=8, moe_impl="dense")

    key = jax.random.PRNGKey(1)
    params = tfm.init_params(cfg, key)
    B, S0 = shape.global_batch, 17
    tokens = jax.random.randint(key, (B, S0 + 1), 0, cfg.vocab_size)

    _, cache0 = tfm.prefill(cfg, params, {"tokens": tokens[:, :S0]}, rt0,
                            max_len=shape.seq_len)
    logits0, _ = tfm.decode_step(cfg, params, cache0, tokens[:, S0:],
                                 jnp.asarray(S0, jnp.int32), rt0)

    with par.use_mesh(mesh):
        pshard = par.param_shardings(cfg, plan, jax.eval_shape(lambda: params))
        params_s = jax.device_put(params, pshard)
        cshapes = jax.eval_shape(lambda: cache0)
        cshard = par.cache_shardings(cfg, plan, cshapes)
        cache_s = jax.device_put(cache0, cshard)
        logits_s, _ = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(cfg, p, c, t, pos, rt_s),
            out_shardings=(None, cshard))(
                params_s, cache_s, tokens[:, S0:], jnp.asarray(S0, jnp.int32))

    err = float(jnp.max(jnp.abs(logits0 - jax.device_get(logits_s))))
    assert err < TOL, (arch, err)


@pytest.mark.slow
@pytest.mark.parametrize("arch,attn_override", [
    ("qwen3-0.6b", None),                    # head_tp
    ("qwen2-1.5b", "context"),               # CP
    ("rwkv6-1.6b", None),
    ("jamba-v0.1-52b", None),
    ("deepseek-moe-16b", None),
])
def test_sharded_train_equivalence(eight_devices, arch, attn_override):
    _check_train(arch, attn_override)


@pytest.mark.slow
@pytest.mark.parametrize("arch", [
    "qwen3-0.6b", "h2o-danube-1.8b", "jamba-v0.1-52b",
])
def test_sharded_decode_equivalence(eight_devices, arch):
    _check_decode(arch)
