"""Multi-device SPMD equivalence, run in a subprocess so the main pytest
process keeps a single visible device (the brief forbids a global
--xla_force_host_platform_device_count)."""
import os
import subprocess
import sys

import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "spmd_check.py")
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _run(which):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, SCRIPT, which],
                         capture_output=True, text=True, timeout=1200,
                         env=env)
    if res.returncode != 0:
        raise AssertionError(
            f"spmd_check {which} failed:\n{res.stdout[-4000:]}\n{res.stderr[-4000:]}")
    assert "SPMD checks passed" in res.stdout


@pytest.mark.slow
def test_sharded_train_equivalence():
    _run("train")


@pytest.mark.slow
def test_sharded_decode_equivalence():
    _run("decode")


@pytest.mark.slow
def test_pipeline_parallel_equivalence():
    script = os.path.join(os.path.dirname(__file__), "pipeline_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, timeout=1200, env=env)
    if res.returncode != 0:
        raise AssertionError(
            f"pipeline_check failed:\n{res.stdout[-4000:]}\n{res.stderr[-4000:]}")
    assert "PIPELINE checks passed" in res.stdout
