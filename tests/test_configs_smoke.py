"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned arch runs one forward + one train step + one decode step on CPU,
asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config, list_archs, reduced, supports_shape
from repro.launch.specs import concrete_train_batch
from repro.models import Runtime, forward, init_params, loss_fn
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.train.trainer import TrainConfig, make_train_step

RT = Runtime(rwkv_chunk=8, mamba_chunk=8, moe_impl="dense")
ARCHS = list_archs(assigned_only=True)


def test_ten_archs_assigned():
    assert len(ARCHS) == 10
    families = {get_config(a).family for a in ARCHS}
    assert families == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = reduced(get_config(arch))
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = concrete_train_batch(cfg, 2, 32, key)
    logits, _, aux = forward(cfg, params, batch, RT)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    step = make_train_step(cfg, RT, TrainConfig(opt=AdamWConfig(lr=1e-3)))
    opt = init_opt_state(params)
    p2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"])) and metrics["grad_norm"] > 0
    assert int(opt2["step"]) == 1
    # parameters actually moved
    moved = sum(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases_two_steps(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = concrete_train_batch(cfg, 2, 32, key)
    step = jax.jit(make_train_step(cfg, RT, TrainConfig(opt=AdamWConfig(lr=3e-3))))
    opt = init_opt_state(params)
    losses = []
    for _ in range(4):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_long_500k_support_matrix():
    long = SHAPES["long_500k"]
    runners = {a for a in ARCHS if supports_shape(get_config(a), long)}
    assert runners == {"rwkv6-1.6b", "jamba-v0.1-52b", "h2o-danube-1.8b"}
