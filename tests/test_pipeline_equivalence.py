"""Parallelism-equivalence tier for pipeline strategies: `fsdp_pp<k>_mb<m>`
specs lowered through Strategy.to_plan must produce the same loss, grads,
and updated params as the pp=1 baseline (fp32, tiny transformer) —
including the grad-accumulation x pipeline-microbatch composition — and
the executed GPipe schedule's measured bubble must agree with the cost
model's (P-1)/(M+P-1) charge (recorded in the dryrun artifact)."""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro import strategy as strategy_lib
from repro.configs import ShapeConfig, get_config, reduced
from repro.core import parallel as par
from repro.launch.specs import concrete_train_batch
from repro.models import transformer as tfm
from repro.models.layers import Runtime
from repro.optim import init_opt_state
from repro.train.trainer import (TrainConfig, make_train_step,
                                 place_train_state)

TOL = 1e-3


def _tiny_cfg():
    return reduced(get_config("qwen3-0.6b"), n_layers=4, d_model=128)


def _run_step(cfg, rt, tc, params, batch, plan=None):
    """One train step; sharded per plan when given, else single device."""
    step = make_train_step(cfg, rt, tc)
    opt = init_opt_state(params)
    if plan is None:
        return step(params, opt, batch)
    with par.use_mesh(plan.mesh):
        params_s, opt_s, batch_s, pshard, _ = place_train_state(
            cfg, plan, params, opt, batch)
        return jax.jit(step, out_shardings=(pshard, None, None))(
            params_s, opt_s, batch_s)


def _assert_equivalent(cfg, spec, grad_accum=1, global_batch=8, seq_len=32):
    topo = strategy_lib.host_topology()
    shape = ShapeConfig("eq", seq_len, global_batch, "train")
    strat = strategy_lib.parse(spec)
    plan = strat.to_plan(cfg, topo, shape)
    assert plan.pipe == "pipe" and plan.pipe_size == strat.pp

    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = concrete_train_batch(cfg, global_batch, seq_len, key)
    tc = TrainConfig(grad_accum=grad_accum)

    rt1 = Runtime(attn_min_chunked_len=seq_len * 2)
    p1, _, m1 = _run_step(cfg, rt1, tc, params, batch)

    rt2 = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                           compute_dtype=jnp.float32, remat=False,
                           attn_min_chunked_len=seq_len * 2)
    assert rt2.pipeline_microbatches == strat.microbatches
    p2, _, m2 = _run_step(cfg, rt2, tc, params, batch, plan)

    dl = abs(float(m1["loss"]) - float(m2["loss"]))
    g1, g2 = float(m1["grad_norm"]), float(m2["grad_norm"])
    rel_g = abs(g1 - g2) / max(g1, 1e-6)
    dp = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert dl < TOL, (spec, dl)
    assert rel_g < TOL, (spec, rel_g)
    assert dp < 1e-2, (spec, dp)


@pytest.mark.parametrize("spec", ["fsdp_pp2_mb4", "fsdp_pp4_mb8"])
def test_pp_matches_baseline(eight_devices, spec):
    """pp>1 loss/grads/updated params == pp=1 single-device baseline."""
    _assert_equivalent(_tiny_cfg(), spec)


@pytest.mark.parametrize("spec", ["fsdp_pp2_mb8_1f1b", "fsdp_pp2_mb4_1f1b",
                                  "fsdp_pp4_mb8_1f1b"])
def test_1f1b_matches_baseline(eight_devices, spec):
    """ISSUE 5 acceptance: 1F1B specs train end-to-end through the full
    Strategy lowering and match the sequential oracle (loss + grads) —
    the custom-vjp combined tick loop, not GPipe's transposed scan."""
    _assert_equivalent(_tiny_cfg(), spec)


@pytest.mark.parametrize("spec", ["fsdp_tp2_pp2_mb4", "fsdp_tp2_pp2_mb4_1f1b",
                                  "fsdp_cp2_pp2_mb4"])
def test_pp_composes_with_model_axis(eight_devices, spec):
    """ISSUE 5 acceptance: pp2 x tp2 (Megatron psums inside the stage;
    stage params stay model-sharded instead of replicated) and pp2 x cp2
    (sequence sharded inside the stage, gathered-KV attention) lower,
    train, and match the single-device baseline."""
    _assert_equivalent(_tiny_cfg(), spec)


def test_pp_composes_with_grad_accum(eight_devices):
    """GA slices the batch, the pipeline splits each slice into M
    microbatches; loss/grad scaling must match the GA-only baseline."""
    _assert_equivalent(_tiny_cfg(), "fsdp_pp2_mb2_ga2", grad_accum=2)


def test_pp_threads_moe_aux_loss(eight_devices):
    """pp > 1 now composes with MoE: the aux load-balance loss rides
    through the GPipe schedule alongside each microbatch (ISSUE 4
    satellite — the StrategyError that blocked MoE pipelines is gone).
    The per-microbatch aux averaging differs from the full-batch stats by
    O(1/sqrt(T_mb)) * aux_coef, hence the slightly wider tolerance."""
    import dataclasses as dc
    cfg = reduced(get_config("deepseek-moe-16b"), n_layers=4, d_model=128)
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, moe_start_layer=0,
                                         capacity_factor=8.0))
    topo = strategy_lib.host_topology()
    shape = ShapeConfig("eq", 32, 8, "train")
    strat = strategy_lib.parse("fsdp_pp2_mb4")
    plan = strat.to_plan(cfg, topo, shape)     # no StrategyError for MoE

    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = concrete_train_batch(cfg, 8, 32, key)
    tc = TrainConfig()

    rt1 = Runtime(attn_min_chunked_len=64, moe_impl="dropping", moe_groups=1)
    p1, _, m1 = _run_step(cfg, rt1, tc, params, batch)
    rt2 = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                           compute_dtype=jnp.float32, remat=False,
                           attn_min_chunked_len=64)
    p2, _, m2 = _run_step(cfg, rt2, tc, params, batch, plan)

    assert float(m2["aux"]) > 0.0              # the aux loss is not dropped
    dl = abs(float(m1["loss"]) - float(m2["loss"]))
    assert dl < 2e-3, dl
    rel_g = abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) \
        / max(float(m1["grad_norm"]), 1e-6)
    assert rel_g < 2e-3, rel_g
    dp = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert dp < 1e-2, dp


def test_pp_composes_with_ep(eight_devices):
    """ISSUE 5 acceptance: pp2 x ep2 — impossible before this refactor
    (StrategyError) — lowers and matches the non-pipelined dropping
    baseline: MoE layers inside the stage dispatch through the expert
    all-to-all on the 'expert' axis (no nested shard_map), both
    schedules."""
    import dataclasses as dc
    cfg = reduced(get_config("deepseek-moe-16b"), n_layers=4, d_model=128)
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, moe_start_layer=0,
                                         capacity_factor=8.0))
    topo = strategy_lib.host_topology()
    shape = ShapeConfig("eq", 32, 8, "train")
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = concrete_train_batch(cfg, 8, 32, key)
    tc = TrainConfig()

    # oracle: non-pipelined dropping with 4 groups == the 4 (data, expert)
    # token shards the pipeline stage dispatches from
    rt1 = Runtime(attn_min_chunked_len=64, moe_impl="dropping", moe_groups=4)
    p1, _, m1 = _run_step(cfg, rt1, tc, params, batch)

    for spec in ("fsdp_pp2_ep2_mb2", "fsdp_pp2_ep2_mb2_1f1b"):
        strat = strategy_lib.parse(spec)
        plan = strat.to_plan(cfg, topo, shape)   # no StrategyError anymore
        assert plan.pipe == "pipe" and plan.expert == "expert"
        rt2 = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                               compute_dtype=jnp.float32, remat=False,
                               attn_min_chunked_len=64)
        p2, _, m2 = _run_step(cfg, rt2, tc, params, batch, plan)
        assert float(m2["aux"]) > 0.0            # aux loss not dropped
        dl = abs(float(m1["loss"]) - float(m2["loss"]))
        assert dl < 2e-3, (spec, dl)
        rel_g = abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) \
            / max(float(m1["grad_norm"]), 1e-6)
        assert rel_g < 2e-3, (spec, rel_g)
        dp = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
                 for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert dp < 1e-2, (spec, dp)


def test_pp_tp_ep_triple_composition(eight_devices):
    """The full inner mesh at once: pipe2 x model2 x expert2 (all 8
    devices, data axis 1) under 1F1B — Megatron psums, expert all-to-all
    and the pipeline schedule composing in one stage body."""
    import dataclasses as dc
    cfg = reduced(get_config("deepseek-moe-16b"), n_layers=4, d_model=128)
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, moe_start_layer=0,
                                         capacity_factor=8.0))
    topo = strategy_lib.host_topology()
    shape = ShapeConfig("eq", 32, 8, "train")
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = concrete_train_batch(cfg, 8, 32, key)
    tc = TrainConfig()

    # oracle groups == the 2 expert-axis token shards the stage dispatches
    rt1 = Runtime(attn_min_chunked_len=64, moe_impl="dropping", moe_groups=2)
    p1, _, m1 = _run_step(cfg, rt1, tc, params, batch)

    strat = strategy_lib.parse("fsdp_tp2_pp2_ep2_mb2_1f1b")
    plan = strat.to_plan(cfg, topo, shape)
    assert dict(plan.mesh.shape) == {"pipe": 2, "data": 1, "expert": 2,
                                     "model": 2}
    rt2 = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                           compute_dtype=jnp.float32, remat=False,
                           attn_min_chunked_len=64)
    p2, _, m2 = _run_step(cfg, rt2, tc, params, batch, plan)
    assert float(m2["aux"]) > 0.0
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    rel_g = abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) \
        / max(float(m1["grad_norm"]), 1e-6)
    assert rel_g < 2e-3, rel_g
    dp = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert dp < 1e-2, dp


def test_pp_ep_needs_expert_sharded_microbatch(eight_devices):
    """pp x ep with microbatch rows that cannot shard over the expert
    axis is rejected at to_plan (the in-stage all-to-all would overcount
    expert grads on replicated tokens)."""
    import dataclasses as dc
    from repro.strategy import StrategyError
    cfg = reduced(get_config("deepseek-moe-16b"), n_layers=4, d_model=128)
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, moe_start_layer=0))
    topo = strategy_lib.host_topology()
    shape = ShapeConfig("eq", 32, 8, "train")
    with pytest.raises(StrategyError):
        # 8 / mb4 = 2 rows over data2 x expert2: expert axis unoccupied
        strategy_lib.parse("fsdp_pp2_ep2_mb4").to_plan(cfg, topo, shape)


def test_pp_matches_executed_fsdp_strategy(eight_devices):
    """pp>1 also agrees with the *executed* fsdp strategy (not just the
    single-device oracle): same lowering API, two points of the space."""
    cfg = _tiny_cfg()
    topo = strategy_lib.host_topology()
    shape = ShapeConfig("eq", 32, 8, "train")
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = concrete_train_batch(cfg, 8, 32, key)
    tc = TrainConfig()

    metrics = {}
    for spec in ("fsdp", "fsdp_pp2_mb4"):
        plan = strategy_lib.parse(spec).to_plan(cfg, topo, shape)
        rt = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32, remat=False,
                              attn_min_chunked_len=64)
        _, _, m = _run_step(cfg, rt, tc, params, batch, plan)
        metrics[spec] = m
    dl = abs(float(metrics["fsdp"]["loss"])
             - float(metrics["fsdp_pp2_mb4"]["loss"]))
    assert dl < TOL, dl


def test_train_cli_pp_on_kernels(eight_devices, tmp_path):
    """The acceptance command: --strategy fsdp_pp2_mb8 --kernels pallas
    completes training steps on 8 virtual CPU devices."""
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)         # train.py forces 8 fake devices
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--strategy", "fsdp_pp2_mb8", "--kernels", "pallas",
         "--reduced", "--steps", "2", "--seq_len", "64", "--log_every", "1"],
        capture_output=True, text=True, timeout=1200, env=env)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "done: loss" in res.stdout, res.stdout[-3000:]


@pytest.mark.slow
def test_dryrun_artifact_bubble_within_20pct(eight_devices, tmp_path):
    """--measure_bubble writes a measured bubble fraction into the dryrun
    artifact that validates the cost model's (P-1)/(M+P-1) term."""
    from repro.launch import dryrun
    rec = dryrun.run_one("qwen3-0.6b", "train_4k", False, str(tmp_path),
                         strategy="fsdp_pp2_mb8", topology="host",
                         use_reduced=True, measure_bubble=True)
    assert rec["status"] == "ok", rec
    _, label = dryrun.run_label("qwen3-0.6b", "train_4k", False,
                                "fsdp_pp2_mb8", "", "host")
    with open(os.path.join(str(tmp_path), label + ".json")) as f:
        artifact = json.load(f)
    pipe = artifact["pipeline"]
    assert pipe["pp"] == 2 and pipe["microbatches"] == 8
    pred = pipe["bubble_predicted"]
    assert pred == pytest.approx(1 / 9)
    attempts = [pipe["bubble_measured"]]
    # wall-clock two-point fits on a loaded CI runner can be noisy: allow
    # up to two higher-effort re-measurements before declaring the cost
    # model's bubble term invalid — any one agreeing measurement passes
    from repro.perf.pipeline_probe import measure_bubble
    for n_iter in (5, 7):
        if min(abs(m - pred) / pred for m in attempts) < 0.20:
            break
        cfg = reduced(get_config("qwen3-0.6b"), n_layers=4)
        retry = measure_bubble(cfg, strategy_lib.parse("fsdp_pp2_mb8"),
                               strategy_lib.host_topology(), n_iter=n_iter)
        attempts.append(retry["bubble_measured"])
    assert min(abs(m - pred) / pred for m in attempts) < 0.20, \
        (attempts, pred)
