"""Multi-device SPMD equivalence checks — run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (see test_spmd.py).

Asserts that the sharded train step (FSDP x TP / context-parallel plans on
a (2, 4) mesh) produces the same loss/gradients as the single-device step,
and that a sharded decode step matches the unsharded one.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8").strip()

import functools
import sys

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get_config, reduced
from repro.core import parallel as par
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import concrete_train_batch
from repro.models import transformer as tfm
from repro.models.layers import Runtime
from repro.optim import init_opt_state
from repro.train.trainer import TrainConfig, make_train_step

TOL = 5e-3


def check_train(arch: str, attn_override=None):
    cfg = reduced(get_config(arch), d_model=256)
    mesh = make_host_mesh(data=2, model=4)
    shape = ShapeConfig("t", 64, 4, "train")
    plan = par.choose_plan(cfg, mesh, shape, attn_override=attn_override)
    rt_single = Runtime(rwkv_chunk=8, mamba_chunk=8, moe_impl="dropping",
                        moe_groups=1, attn_min_chunked_len=32,
                        attn_q_chunk=16, attn_kv_chunk=16)
    rt_shard = par.make_runtime(
        cfg, plan, shape, param_dtype=jnp.float32, compute_dtype=jnp.float32,
        remat=False, rwkv_chunk=8, mamba_chunk=8,
        attn_min_chunked_len=32, attn_q_chunk=64 if plan.attn == "context" else 16,
        attn_kv_chunk=16, moe_impl="dropping")

    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = concrete_train_batch(cfg, shape.global_batch, shape.seq_len, key)
    tc = TrainConfig()

    # single device
    p1, o1, m1 = make_train_step(cfg, rt_single, tc)(
        params, init_opt_state(params), batch)

    # sharded
    pshard = par.param_shardings(cfg, plan, jax.eval_shape(lambda: params))
    with par.use_mesh(mesh):
        params_s = jax.device_put(params, pshard)
        opt_s = jax.device_put(init_opt_state(params),
                               {"m": pshard, "v": pshard,
                                "step": par.fitted(plan, par.P(), ())})
        batch_s = jax.device_put(batch, par.batch_specs(cfg, plan, batch))
        step = jax.jit(make_train_step(cfg, rt_shard, tc),
                       out_shardings=(pshard, None, None))
        p2, o2, m2 = step(params_s, opt_s, batch_s)

    dl = abs(float(m1["loss"]) - float(m2["loss"]))
    dg = abs(float(m1["grad_norm"]) - float(m2["grad_norm"]))
    rel_g = dg / max(float(m1["grad_norm"]), 1e-6)
    # updated params agree
    dp = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    print(f"  {arch} ({plan.attn}): dloss={dl:.2e} dgrad_rel={rel_g:.2e} "
          f"dparam={dp:.2e}")
    assert dl < TOL, (arch, dl)
    assert rel_g < TOL, (arch, rel_g)
    assert dp < 5e-2, (arch, dp)


def check_decode(arch: str):
    cfg = reduced(get_config(arch), d_model=256)
    mesh = make_host_mesh(data=2, model=4)
    shape = ShapeConfig("d", 64, 4, "decode")
    plan = par.choose_plan(cfg, mesh, shape)
    rt0 = Runtime(rwkv_chunk=8, mamba_chunk=8, moe_impl="dense")
    rt_s = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                            compute_dtype=jnp.float32, remat=False,
                            rwkv_chunk=8, mamba_chunk=8, moe_impl="dense")

    key = jax.random.PRNGKey(1)
    params = tfm.init_params(cfg, key)
    B, S0 = shape.global_batch, 17
    tokens = jax.random.randint(key, (B, S0 + 1), 0, cfg.vocab_size)

    _, cache0 = tfm.prefill(cfg, params, {"tokens": tokens[:, :S0]}, rt0,
                            max_len=shape.seq_len)
    logits0, _ = tfm.decode_step(cfg, params, cache0, tokens[:, S0:],
                                 jnp.asarray(S0, jnp.int32), rt0)

    with par.use_mesh(mesh):
        pshard = par.param_shardings(cfg, plan, jax.eval_shape(lambda: params))
        params_s = jax.device_put(params, pshard)
        cshapes = jax.eval_shape(lambda: cache0)
        cshard = par.cache_shardings(cfg, plan, cshapes)
        cache_s = jax.device_put(cache0, cshard)
        step = jax.jit(functools.partial(tfm.decode_step, cfg, rt=rt_s),
                       static_argnames=())
        logits_s, _ = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(cfg, p, c, t, pos, rt_s),
            out_shardings=(None, cshard))(
                params_s, cache_s, tokens[:, S0:], jnp.asarray(S0, jnp.int32))

    err = float(jnp.max(jnp.abs(logits0 - jax.device_get(logits_s))))
    print(f"  {arch} decode ({plan.decode_cache_axes}): err={err:.2e}")
    assert err < TOL, (arch, err)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    print(f"devices: {len(jax.devices())}")
    if which in ("all", "train"):
        check_train("qwen3-0.6b")                      # head_tp
        check_train("qwen2-1.5b", attn_override="context")  # CP
        check_train("rwkv6-1.6b")
        check_train("jamba-v0.1-52b")
        check_train("deepseek-moe-16b")
    if which in ("all", "decode"):
        check_decode("qwen3-0.6b")
        check_decode("h2o-danube-1.8b")
        check_decode("jamba-v0.1-52b")
    print("SPMD checks passed")
