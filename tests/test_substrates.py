"""Optimizer / data / checkpoint / HLO-parser / schedule unit tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpointing import latest_step, restore_checkpoint, save_checkpoint
from repro.data import Batcher, BinTokenSource, SyntheticSource
from repro.optim import (AdamWConfig, adamw_update, global_norm,
                         init_opt_state, linear_warmup_cosine)
from repro.perf.hlo import collective_stats, collective_stats_flat


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "scale": jnp.ones((2,))}
    target = jnp.asarray([1.0, 2.0])
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2)
                     + 0 * jnp.sum(p["scale"]))(params)
        params, opt, _ = adamw_update(cfg, params, g, opt)
    assert float(jnp.max(jnp.abs(params["w"] - target))) < 1e-2


def test_adamw_grad_clipping():
    params = {"w": jnp.zeros((4,))}
    opt = init_opt_state(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(AdamWConfig(grad_clip=1.0), params, g, opt)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_adamw_no_decay_on_norm_scales():
    params = {"scale": jnp.ones((8,)), "w": jnp.ones((8, 8))}
    opt = init_opt_state(params)
    g = {"scale": jnp.zeros((8,)), "w": jnp.zeros((8, 8))}
    p2, _, _ = adamw_update(AdamWConfig(lr=1.0, weight_decay=0.5), params, g, opt)
    assert jnp.allclose(p2["scale"], 1.0)        # untouched (no grad, no decay)
    assert not jnp.allclose(p2["w"], 1.0)        # decayed


@given(step=st.integers(0, 10000))
@settings(max_examples=100, deadline=None)
def test_schedule_bounded(step):
    v = float(linear_warmup_cosine(jnp.asarray(step), 100, 10000))
    assert 0.0 <= v <= 1.0


def test_schedule_warmup_then_decay():
    s = lambda t: float(linear_warmup_cosine(jnp.asarray(t), 100, 1000))
    assert s(10) < s(99) <= 1.0
    assert s(100) >= s(500) >= s(999)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_batcher_shapes_and_determinism():
    b1 = next(iter(Batcher(SyntheticSource(512, seed=7), 64, 4)))
    b2 = next(iter(Batcher(SyntheticSource(512, seed=7), 64, 4)))
    assert b1["tokens"].shape == (4, 64) and b1["labels"].shape == (4, 64)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].max() < 512 and b1["tokens"].min() >= 0


def test_bin_token_source(tmp_path):
    data = np.arange(1000, dtype=np.uint16) % 256
    path = tmp_path / "toks.bin"
    data.tofile(path)
    batch = next(iter(Batcher(BinTokenSource(str(path), chunk=128), 16, 2)))
    assert batch["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(batch["tokens"][0], np.arange(16))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    restored = restore_checkpoint(str(tmp_path), 5, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

SYNTHETIC_HLO = """
HloModule test

%body.1 (arg: (s32[], f32[128])) -> (s32[], f32[128]) {
  %ag = f32[256]{0} all-gather(f32[128] %x), replica_groups={}
  ROOT %t = tuple(...)
}

ENTRY %main (p: f32[128]) -> f32[256] {
  %w = (s32[], f32[128]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %ar = f32[64]{0} all-reduce(f32[64] %y), to_apply=%add
  ROOT %out = f32[256] get-tuple-element(%w), index=1
}
"""


def test_collective_stats_scales_while_bodies():
    stats = collective_stats(SYNTHETIC_HLO)
    assert stats["all-gather"]["bytes"] == 10 * 256 * 4
    assert stats["all-gather"]["count"] == 10
    assert stats["all-reduce"]["bytes"] == 64 * 4


def test_collective_stats_flat_counts_once():
    stats = collective_stats_flat(SYNTHETIC_HLO)
    assert stats["all-gather"]["bytes"] == 256 * 4


def test_collective_stats_on_real_lowering():
    """8-fake-device lowering in a subprocess-free way is not possible here
    (1 visible device), so check a dot-sharded module lowers parse-clean."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import sys; sys.path.insert(0, "src")
        from repro.perf.hlo import collective_stats
        from repro.core.parallel import use_mesh
        mesh = jax.make_mesh((4,), ("x",))
        def f(a):
            b = jax.lax.with_sharding_constraint(a, jax.NamedSharding(mesh, P("x")))
            def body(c, x): return c + (b * x).sum(), None
            return jax.lax.scan(body, 0.0, jnp.arange(5.0))[0]
        with use_mesh(mesh):
            sds = jax.ShapeDtypeStruct((16,), jnp.float32,
                                       sharding=jax.NamedSharding(mesh, P(None)))
            txt = jax.jit(f).lower(sds).compile().as_text()
        s = collective_stats(txt)
        print("PARSED", sum(v["count"] for v in s.values()))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=env, cwd=os.path.join(
                             os.path.dirname(__file__), os.pardir))
    assert res.returncode == 0, res.stderr[-2000:]
    assert "PARSED" in res.stdout


# ---------------------------------------------------------------------------
# global norm property
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(-100, 100), min_size=1, max_size=16))
@settings(max_examples=50, deadline=None)
def test_global_norm_matches_numpy(vals):
    tree = {"x": jnp.asarray(vals, jnp.float32)}
    assert float(global_norm(tree)) == pytest.approx(
        float(np.linalg.norm(np.asarray(vals, np.float32))), rel=1e-4, abs=1e-5)
