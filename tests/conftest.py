"""Shared pytest config.

Tier-1 must *collect* without optional dev deps: several test modules use
hypothesis property tests.  When hypothesis is absent (the bare container),
install a stub module whose ``@given`` turns each property test into a
skip, so the plain unit tests in the same modules still run.  Install
``requirements-dev.txt`` to run the real property tests.
"""
import sys
import types

import pytest


def _install_hypothesis_stub():
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    def _strategy_stub(*_a, **_k):
        return None

    for name in ("floats", "integers", "booleans", "sampled_from", "lists",
                 "tuples", "text", "one_of", "just"):
        setattr(st, name, _strategy_stub)

    def given(*_a, **_k):
        def deco(fn):
            # no functools.wraps: pytest must see (*args, **kwargs), not the
            # property-test signature (it would treat params as fixtures)
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.assume = lambda *_a, **_k: True
    mod.__is_repro_stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_stub()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (minutes)")
