"""Shared pytest config.

Multi-device coverage: the whole suite runs under 8 virtual XLA host
devices (set here, before any test imports jax and the CPU backend
initializes), so pipeline/SPMD equivalence tests run in-process in tier-1
instead of shelling out per test.  Respects an explicit XLA_FLAGS device
count from the environment (CI sets the same value).

Tier-1 must *collect* without optional dev deps: several test modules use
hypothesis property tests.  When hypothesis is absent (the bare container),
install a stub module whose ``@given`` turns each property test into a
skip, so the plain unit tests in the same modules still run.  Install
``requirements-dev.txt`` to run the real property tests.
"""
import os
import sys
import types

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro.launch.devices import force_host_device_count

force_host_device_count(8)

import pytest


def _install_hypothesis_stub():
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    def _strategy_stub(*_a, **_k):
        return None

    for name in ("floats", "integers", "booleans", "sampled_from", "lists",
                 "tuples", "text", "one_of", "just", "fixed_dictionaries",
                 "dictionaries"):
        setattr(st, name, _strategy_stub)

    def given(*_a, **_k):
        def deco(fn):
            # no functools.wraps: pytest must see (*args, **kwargs), not the
            # property-test signature (it would treat params as fixtures)
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.assume = lambda *_a, **_k: True
    mod.__is_repro_stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_stub()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device equivalence tests (minutes)")


@pytest.fixture
def eight_devices():
    """The 8 virtual host devices the pipeline/SPMD tests mesh over."""
    import jax
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices; XLA_FLAGS was fixed before this "
                    "conftest could set the virtual device count")
    return jax.devices()[:8]
