"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles in
``repro.kernels.ref`` (interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rwkv6 import wkv6

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("B,S,H,Kv,D", [
    (2, 128, 4, 2, 64),     # GQA
    (1, 256, 4, 4, 64),     # MHA
    (1, 384, 8, 1, 128),    # MQA (granite)
    (2, 96, 6, 2, 64),      # ragged (pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, S, H, Kv, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Kv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Kv, D), dtype)
    out = flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True)
    expect = ref.attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert out.shape == q.shape and out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - expect.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = flash_attention(q, k, v, window=window, block_q=64, block_kv=64,
                          interpret=True)
    expect = ref.attention_ref(q, k, v, window=window)
    assert float(jnp.max(jnp.abs(out - expect))) < 2e-5


@pytest.mark.parametrize("blocks", [(64, 64), (128, 64), (64, 128), (128, 128)])
def test_flash_attention_block_shapes(blocks):
    bq, bk = blocks
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = flash_attention(q, k, v, block_q=bq, block_kv=bk, interpret=True)
    expect = ref.attention_ref(q, k, v)
    assert float(jnp.max(jnp.abs(out - expect))) < 2e-5


# ---------------------------------------------------------------------------
# custom_vjp grad consistency: pallas backward kernels vs jax.grad of the
# jnp oracle (fp32, interpret mode on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Kv,D,window", [
    (2, 128, 4, 2, 64, 0),      # GQA causal
    (1, 256, 4, 4, 64, 64),     # sliding window
    (1, 160, 4, 2, 64, 0),      # non-block-multiple S (pad path)
    (1, 128, 8, 1, 64, 0),      # MQA
])
def test_flash_attention_grads(B, S, H, Kv, D, window):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, S, Kv, D)) * 0.5
    v = jax.random.normal(ks[2], (B, S, Kv, D)) * 0.5
    cot = jax.random.normal(ks[3], (B, S, H, D))

    def loss_pallas(q, k, v):
        out = flash_attention(q, k, v, window=window, block_q=64,
                              block_kv=64, interpret=True)
        return jnp.sum(out * cot)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, window=window) * cot)

    g_pl = jax.grad(loss_pallas, (0, 1, 2))(q, k, v)
    g_rf = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", g_pl, g_rf):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < 1e-3, (name, err)


@pytest.mark.parametrize("S", [160, 200, 300])
def test_flash_attention_default_blocks_ragged_s(S):
    """Default 128/256 blocks with 128 < S < 2*block_q: the padded length
    must stay a multiple of both block sizes (regression: tail q-blocks
    were silently dropped, NaN out/grads)."""
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (1, S, 4, 64)) * 0.5
    k = jax.random.normal(ks[1], (1, S, 2, 64)) * 0.5
    v = jax.random.normal(ks[2], (1, S, 2, 64)) * 0.5
    cot = jax.random.normal(ks[3], q.shape)
    out = flash_attention(q, k, v, interpret=True)
    expect = ref.attention_ref(q, k, v)
    assert float(jnp.max(jnp.abs(out - expect))) < 2e-5
    g_pl = jax.grad(lambda q, k, v: jnp.sum(flash_attention(
        q, k, v, interpret=True) * cot), (0, 1, 2))(q, k, v)
    g_rf = jax.grad(lambda q, k, v: jnp.sum(
        ref.attention_ref(q, k, v) * cot), (0, 1, 2))(q, k, v)
    for a, b in zip(g_pl, g_rf):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-3


def test_flash_attention_grads_mixed_blocks():
    """bq != bk exercises both backward grids' independent block offsets."""
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (1, 256, 4, 64)) * 0.5
    k = jax.random.normal(ks[1], (1, 256, 2, 64)) * 0.5
    v = jax.random.normal(ks[2], (1, 256, 2, 64)) * 0.5
    cot = jax.random.normal(ks[3], q.shape)
    g_pl = jax.grad(lambda q, k, v: jnp.sum(flash_attention(
        q, k, v, block_q=128, block_kv=64, interpret=True) * cot),
        (0, 1, 2))(q, k, v)
    g_rf = jax.grad(lambda q, k, v: jnp.sum(
        ref.attention_ref(q, k, v) * cot), (0, 1, 2))(q, k, v)
    for a, b in zip(g_pl, g_rf):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-3


@pytest.mark.parametrize("shape", [(4, 7, 256), (2, 128, 512), (3, 384)])
def test_rmsnorm_grads(shape):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], shape)
    scale = jax.random.normal(ks[1], shape[-1:])
    cot = jax.random.normal(ks[2], shape)
    g_pl = jax.grad(lambda x, s: jnp.sum(rmsnorm(
        x, s, block_rows=64, interpret=True) * cot), (0, 1))(x, scale)
    g_rf = jax.grad(lambda x, s: jnp.sum(
        ref.rmsnorm_ref(x, s) * cot), (0, 1))(x, scale)
    for name, a, b in zip(("dx", "dscale"), g_pl, g_rf):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < 1e-3, (name, err)


@pytest.mark.parametrize("shape", [(4, 7, 256), (2, 128, 512), (3, 384),
                                   (1, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    scale = jax.random.normal(KEY, shape[-1:], dtype)
    out = rmsnorm(x, scale, interpret=True)
    expect = ref.rmsnorm_ref(x, scale)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - expect.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("B,T,H,N,chunk", [
    (2, 64, 2, 32, 16),
    (1, 100, 3, 64, 32),    # ragged pad
    (2, 33, 2, 16, 8),
    (1, 128, 1, 64, 64),
])
def test_wkv6_vs_recurrent(B, T, H, N, chunk):
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, N)) * 0.5 - 2.5))
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    y, s = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    yr, sr = ref.wkv6_ref(r, k, v, w, u, jnp.zeros((B, H, N, N)))
    assert float(jnp.max(jnp.abs(y - yr))) < 1e-3
    assert float(jnp.max(jnp.abs(s - sr))) < 1e-3


def test_wkv6_matches_model_chunked_path():
    """The model's jnp chunked WKV and the Pallas kernel agree."""
    from repro.models.rwkv6 import wkv_chunked
    ks = jax.random.split(KEY, 5)
    B, T, H, N = 2, 64, 2, 32
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, N)) * 0.5 - 2.5))
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    y1, s1 = wkv6(r, k, v, w, u, chunk=16, interpret=True)
    y2, s2 = wkv_chunked(r, k, v, w, u, jnp.zeros((B, H, N, N)), 16)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(s1 - s2))) < 1e-4
