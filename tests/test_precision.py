"""Mixed precision as a strategy degree (PR 8).

Covers the precision policy end-to-end: spec tokens -> descriptor ->
plan -> Runtime dtypes; bf16 train-step numerics against f32; the
dtype-aware cost-model byte terms; the pinned planner crossover that
flips when precision changes; bit-stable bf16 resume; and the
checkpoint dtype-exactness fixes that ride along.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import strategy as strategy_lib
from repro.checkpointing import checkpoint as ckpt_lib
from repro.configs import ShapeConfig, get_config, reduced
from repro.core import costmodel as cm
from repro.core import parallel as par
from repro.data import Batcher, SyntheticSource
from repro.launch.specs import concrete_train_batch
from repro.models import transformer as tfm
from repro.optim import init_opt_state
from repro.strategy.descriptor import StrategyError
from repro.train.trainer import (TrainConfig, make_train_step,
                                 place_train_state, train_loop)


def _tiny_cfg(**kw):
    return reduced(get_config("qwen3-0.6b"), n_layers=2, d_model=64, **kw)


def _one_step(cfg, spec, shape, tc=None):
    """Lower + run one train step under ``spec``'s precision policy."""
    topo = strategy_lib.host_topology()
    strat = strategy_lib.parse(spec)
    plan = strat.to_plan(cfg, topo, shape)
    rt = par.make_runtime(cfg, plan, shape, remat=False,
                          attn_min_chunked_len=256)
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = concrete_train_batch(cfg, shape.global_batch, shape.seq_len, key)
    with par.use_mesh(plan.mesh):
        ps, os_, bs, pshard, _ = place_train_state(
            cfg, plan, params, init_opt_state(params), batch)
        step = jax.jit(make_train_step(cfg, rt, tc or TrainConfig()),
                       out_shardings=(pshard, None, None))
        p2, _, metrics = step(ps, os_, bs)
    return rt, p2, {k: float(v) for k, v in metrics.items()}


# ---------------------------------------------------------------------------
# spec tokens + policy lowering
# ---------------------------------------------------------------------------

def test_precision_spec_round_trip():
    # f32 is the default and emits no token, so legacy specs round-trip
    assert strategy_lib.parse("fsdp").precision == "f32"
    assert strategy_lib.parse("fsdp").format() == "fsdp"
    for spec, prec in (("fsdp_bf16", "bf16"), ("hsdp_tp2_fp8", "fp8"),
                       ("fsdp_pp2_mb4_1f1b_bf16", "bf16")):
        s = strategy_lib.parse(spec)
        assert s.precision == prec
        assert s.format() == spec
        assert strategy_lib.parse(s.format()) == s


def test_precision_spec_rejects():
    with pytest.raises(StrategyError):
        strategy_lib.parse("fsdp_bf16_fp8")        # duplicate degree
    with pytest.raises(StrategyError):
        strategy_lib.Strategy(dp_mode="fsdp", precision="fp16")
    # cost-model side: unknown precision fails valid()
    s = dataclasses.replace(cm.Strategy(8), precision="fp16")
    assert not s.valid()


def test_precision_policy_reaches_runtime():
    cfg = _tiny_cfg()
    shape = ShapeConfig("prec", 16, 4, "train")
    topo = strategy_lib.host_topology()
    cases = {
        "fsdp": (jnp.float32, jnp.float32, False),
        "fsdp_bf16": (jnp.float32, jnp.bfloat16, False),
        "fsdp_fp8": (jnp.float32, jnp.bfloat16, True),
    }
    for spec, (pdt, cdt, gathers) in cases.items():
        plan = strategy_lib.parse(spec).to_plan(cfg, topo, shape)
        rt = par.make_runtime(cfg, plan, shape)
        assert rt.param_dtype == pdt, spec
        assert rt.compute_dtype == cdt, spec
        # fp8 comms only exist on the per-layer gather path, so the
        # policy turns it on by default
        assert (rt.gather_params is not None) == gathers, spec
    assert par.PRECISION_POLICIES["fp8"].comm_dtype == "float8_e4m3fn"


# ---------------------------------------------------------------------------
# train-step numerics
# ---------------------------------------------------------------------------

def test_bf16_train_step_numerics_match_f32():
    cfg = _tiny_cfg()
    shape = ShapeConfig("prec", 32, 4, "train")
    rt32, p32, m32 = _one_step(cfg, "fsdp", shape)
    rt16, p16, m16 = _one_step(cfg, "fsdp_bf16", shape)
    assert rt32.compute_dtype == jnp.float32
    assert rt16.compute_dtype == jnp.bfloat16
    # bf16 forward/backward tracks f32 closely at init scale; master
    # params stay f32 so the update applies at full precision
    assert m16["loss"] == pytest.approx(m32["loss"], rel=2e-2)
    assert np.isfinite(m16["grad_norm"]) and m16["grad_norm"] > 0
    for leaf in jax.tree.leaves(p16):
        assert leaf.dtype == jnp.float32
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_fp8_comm_train_step_runs_and_is_finite():
    cfg = _tiny_cfg()
    shape = ShapeConfig("prec", 32, 4, "train")
    _, params, m = _one_step(cfg, "fsdp_fp8", shape)
    assert np.isfinite(m["loss"])
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_grad_accum_returns_full_metrics():
    """The GA>1 branch used to return metrics={} — aux/nll/ntok were
    silently dropped from logs whenever gradient accumulation was on."""
    cfg = _tiny_cfg()
    shape = ShapeConfig("prec", 32, 4, "train")
    _, p1, m1 = _one_step(cfg, "fsdp", shape)
    _, p2, m2 = _one_step(cfg, "fsdp", shape, TrainConfig(grad_accum=2))
    assert sorted(m1) == sorted(m2)
    assert m2["ntok"] == m1["ntok"]            # token counts sum, not mean
    assert m2["loss"] == pytest.approx(m1["loss"], rel=1e-3)
    assert m2["nll"] == pytest.approx(m1["nll"], rel=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


# ---------------------------------------------------------------------------
# dtype-aware cost model
# ---------------------------------------------------------------------------

def test_costmodel_bytes_scale_with_precision():
    cfg = get_config("llama2-7b")
    hw = cm.HARDWARE["TPUv5e"]
    base = cm.Strategy(256, zero_stage=3)
    r = {p: cm.step_time(cfg, hw, dataclasses.replace(base, precision=p),
                         1024, 2048) for p in ("f32", "bf16", "fp8")}
    ag = {p: r[p].comm_breakdown["fsdp_ag"] for p in r}
    rs = {p: r[p].comm_breakdown["fsdp_rs"] for p in r}
    # gather wire: f32 params are 2x bf16; emulated fp8 halves bf16 again
    assert ag["f32"] == pytest.approx(2 * ag["bf16"], rel=1e-6)
    assert ag["bf16"] == pytest.approx(2 * ag["fp8"], rel=1e-6)
    # grads reduce in f32 under every policy: same absolute bytes
    assert rs["f32"] == pytest.approx(rs["bf16"], rel=1e-6)
    assert rs["bf16"] == pytest.approx(rs["fp8"], rel=1e-6)
    # f32 matmuls run at half the bf16 peak
    assert r["f32"].t_compute == pytest.approx(2 * r["bf16"].t_compute,
                                               rel=1e-6)
    # f32 activations + fp32-stored params cost more memory
    assert r["f32"].memory_per_device > r["bf16"].memory_per_device
    # checkpoint bytes follow the param storage dtype
    assert cm.checkpoint_bytes(cfg, precision="f32") > \
        cm.checkpoint_bytes(cfg, precision="bf16")


def test_planner_sweeps_precision_by_default():
    cfg = get_config("llama2-7b")
    hw = cm.HARDWARE["TPUv5e"]
    topo = strategy_lib.Topology("pod", 256, hw.island, hardware=hw.name,
                                 hbm=16e9)
    shape = ShapeConfig("prec", 2048, 1024, "train")
    ranked = strategy_lib.search(cfg, topo, shape, tps=(1,), cps=(1,),
                                 pps=(1,), eps=(1,), require_lowerable=False,
                                 require_fits=False)
    precs = {p.strategy.precision for p in ranked}
    assert precs == {"f32", "bf16"}
    # at bandwidth-bound scale bf16 dominates the same mesh (half the
    # wire bytes, double the matmul rate): the top pick is a bf16 point
    assert ranked[0].strategy.precision == "bf16"


def test_precision_flips_planner_frontier():
    """Pinned crossover: llama2-70b on 2048 H100s.  At f32, compute is
    slow enough that cp8's ring traffic fully overlaps — context
    parallelism wins.  At bf16 the matmuls run 2x faster, the same comm
    no longer hides, and the flat HSDP mesh takes the frontier.  The
    sharding decision depends on the numeric format — the planner must
    sweep precision to see it."""
    cfg = get_config("llama2-70b")
    hw = cm.HARDWARE["H100"]
    topo = strategy_lib.Topology("flip", 2048, hw.island,
                                 hardware=hw.name, hbm=80e9)
    shape = ShapeConfig("flip", 4096, 4096, "train")

    def wps(spec):
        return strategy_lib.evaluate(
            cfg, strategy_lib.parse(spec), topo, shape).wps

    assert wps("hsdp_cp8") > wps("hsdp")                   # f32: cp8 wins
    assert wps("hsdp_bf16") > wps("hsdp_cp8_bf16")         # bf16: flat wins

    kw = dict(tps=(1,), cps=(1, 8), pps=(1,), eps=(1,),
              require_lowerable=False, require_fits=False)
    top_f32 = strategy_lib.search(cfg, topo, shape,
                                  precisions=("f32",), **kw)[0].spec
    top_bf16 = strategy_lib.search(cfg, topo, shape,
                                   precisions=("bf16",), **kw)[0].spec
    assert top_f32 == "hsdp_cp8"
    assert top_bf16 == "hsdp_bf16"


# ---------------------------------------------------------------------------
# bf16 resume + PRNG restore
# ---------------------------------------------------------------------------

def _make_batches(cfg):
    return Batcher(SyntheticSource(cfg.vocab_size, seed=7), 16, 4)


def test_bf16_resume_bitmatches_uninterrupted(tmp_path):
    cfg = _tiny_cfg()
    shape = ShapeConfig("prec", 16, 4, "train")
    topo = strategy_lib.host_topology()
    plan = strategy_lib.parse("fsdp_bf16").to_plan(cfg, topo, shape)
    rt = par.make_runtime(cfg, plan, shape)
    assert rt.compute_dtype == jnp.bfloat16
    key = jax.random.PRNGKey(0)

    tc_a = TrainConfig(steps=4, warmup=1, log_every=100)
    p_a, _, _ = train_loop(cfg, plan, rt, tc_a, _make_batches(cfg), key=key)

    ckpt_dir = str(tmp_path / "ckpt")
    tc_b1 = TrainConfig(steps=2, warmup=1, log_every=100, ckpt_every=2,
                        ckpt_dir=ckpt_dir)
    train_loop(cfg, plan, rt, tc_b1, _make_batches(cfg), key=key)
    meta = ckpt_lib.load_meta(ckpt_dir, 2)
    assert meta.get("prng") is not None        # PRNG key travels in meta
    tc_b2 = TrainConfig(steps=4, warmup=1, log_every=100, ckpt_every=2,
                        ckpt_dir=ckpt_dir, resume=True)
    p_b, _, _ = train_loop(cfg, plan, rt, tc_b2, _make_batches(cfg), key=key)

    for a, b in zip(jax.tree.leaves(jax.device_get(p_a)),
                    jax.tree.leaves(jax.device_get(p_b))):
        assert np.array_equal(a, b)


def test_prng_key_wrap_round_trips():
    """The restore path in train_loop: key data saved as a plain list must
    rebuild the same key for both typed and raw-uint32 keys."""
    typed = jax.random.key(123)
    kd = np.asarray(jax.random.key_data(typed)).tolist()
    back = jax.random.wrap_key_data(
        jnp.asarray(np.asarray(kd, dtype=np.uint32)),
        impl=jax.random.key_impl(typed))
    assert np.array_equal(jax.random.key_data(typed),
                          jax.random.key_data(back))
    raw = jax.random.PRNGKey(123)
    assert np.array_equal(
        np.asarray(raw),
        np.asarray(jnp.asarray(np.asarray(np.asarray(raw).tolist(),
                                          dtype=np.uint32))))


# ---------------------------------------------------------------------------
# checkpoint dtype exactness (satellite bugfix)
# ---------------------------------------------------------------------------

def test_checkpoint_extended_dtype_round_trip(tmp_path):
    tree = {
        "bf16": jnp.arange(8, dtype=jnp.float32).astype(jnp.bfloat16),
        "fp8": jnp.asarray([1.0, -2.0, 0.5]).astype(jnp.float8_e4m3fn),
        "f16": jnp.asarray([1.5, 2.5], jnp.float16),
        "i8": jnp.asarray([-1, 2, -3], jnp.int8),
        "u8": jnp.asarray([1, 2, 250], jnp.uint8),
    }
    ckpt_lib.save_checkpoint(str(tmp_path), 1, tree)
    out = ckpt_lib.restore_checkpoint(str(tmp_path), 1, tree)
    for k in tree:
        a, b = np.asarray(tree[k]), np.asarray(out[k])
        assert a.dtype == b.dtype, k
        assert np.array_equal(a.view(np.uint8), b.view(np.uint8)), k


def test_checkpoint_rejects_conflated_dtypes(tmp_path):
    """'int8' is a substring of 'uint8' (and 'float16' of 'bfloat16'):
    the old substring check silently loaded the wrong dtype.  A manifest
    dtype the stored bits cannot hold must raise."""
    tree = {"u8": jnp.asarray([1, 2, 250], jnp.uint8),
            "f16": jnp.asarray([1.5, 2.5], jnp.float16)}
    ckpt_lib.save_checkpoint(str(tmp_path), 1, tree)
    man_path = os.path.join(str(tmp_path), "step_00000001", "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    man["leaves"]["u8"]["dtype"] = "int8"        # uint8 bits, int8 claim
    man["leaves"]["f16"]["dtype"] = "bfloat16"   # float16 bits, bf16 claim
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ckpt_lib.CheckpointError) as ei:
        ckpt_lib.restore_checkpoint(str(tmp_path), 1, tree)
    assert "u8" in str(ei.value) and "f16" in str(ei.value)


# ---------------------------------------------------------------------------
# roofline follows the hardware profile (satellite bugfix)
# ---------------------------------------------------------------------------

def test_roofline_peaks_come_from_hardware_profile():
    from repro.perf import roofline
    # the v5e default reproduces the former hard-coded constants exactly
    assert roofline._peaks(None) == (197e12, 819e9, 50e9)
    hw = cm.HARDWARE["H100"]
    peak, hbm, link = roofline._peaks(hw)
    assert (peak, hbm) == (hw.flops_bf16, hw.hbm_bw)
    assert link == hw.intra_bw / hw.rings
