"""Pipeline *schedule* subsystem tests (ISSUE 5): tick-table simulations
must reproduce the analytic bubble/memory formulas, the 1F1B custom-vjp
execution must match the sequential oracle (forward AND gradient) on the
shared 8-virtual-device fixture, and the probe's two-point fit must flag
unreliable measurements instead of reporting a fabricated 0.0 bubble."""
import logging
import time

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.core.parallel import use_mesh
from repro.core.pipeline import (SCHEDULES, batch_axes_spec, bubble_fraction,
                                 get_schedule, inflight_microbatches,
                                 known_schedule, make_pipelined_block_fn,
                                 measure_bubble_fraction, op_tick_counts,
                                 parse_schedule, pipeline_apply,
                                 virtual_stages)
from repro.models.layers import Runtime
from repro.models.transformer import (_apply_layer, _init_layer, _sig,
                                      _tree_stack)


# ---------------------------------------------------------------------------
# tick-table simulation vs analytic formulas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched", ["gpipe", "1f1b"])
@pytest.mark.parametrize("P_,M", [(2, 2), (2, 8), (4, 4), (4, 8), (4, 13),
                                  (8, 8), (8, 32)])
def test_tick_table_matches_formulas(sched, P_, M):
    """The executable loops are index arithmetic over exactly these
    tables: counted idle fraction == bubble_fraction, counted peak
    in-flight == inflight_microbatches."""
    sim = get_schedule(sched).simulate(P_, M)
    assert sim["bubble"] == pytest.approx(bubble_fraction(P_, M, sched))
    assert sim["peak_inflight"] == inflight_microbatches(P_, M, sched)


@pytest.mark.parametrize("P_,M", [(2, 4), (4, 8)])
def test_tick_table_well_formed(P_, M):
    """Every microbatch is forwarded and backwarded exactly once per
    stage, in order, and 1F1B's combined table is 2(M+P-1) ticks."""
    for sched, want_ticks in (("gpipe", 2 * (M + P_ - 1)),
                              ("1f1b", 2 * (M + P_ - 1))):
        table = get_schedule(sched).tick_table(P_, M)
        assert len(table) == want_ticks
        for s in range(P_):
            fs = [j for op, j in (row[s] for row in table) if op == "F"]
            bs = [j for op, j in (row[s] for row in table) if op == "B"]
            assert fs == list(range(M)), (sched, s)
            assert sorted(bs) == list(range(M)), (sched, s)


def test_1f1b_inflight_strictly_smaller_than_gpipe():
    assert inflight_microbatches(4, 16, "1f1b") == 4
    assert inflight_microbatches(4, 16, "gpipe") == 16
    assert inflight_microbatches(4, 4, "1f1b") == 4
    assert bubble_fraction(4, 16, "1f1b") == bubble_fraction(4, 16, "gpipe")


def test_1f1b_rejects_underfilled_pipeline():
    with pytest.raises(ValueError):
        get_schedule("1f1b").tick_table(4, 2)
    with pytest.raises(ValueError):
        get_schedule("unknown")
    with pytest.raises(ValueError):
        bubble_fraction(2, 8, "interleaved")


# ---------------------------------------------------------------------------
# schedule frontier (ISSUE 10): interleaved 1f1b_i<v> and zero-bubble zb
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched", ["1f1b_i2", "1f1b_i3", "zb"])
@pytest.mark.parametrize("P_,M", [(2, 4), (4, 8), (4, 16), (8, 16)])
def test_frontier_tick_tables_match_formulas(sched, P_, M):
    """Same contract the gpipe/1f1b tables honour: the greedy list
    scheduler's counted idle fraction and peak in-flight must equal the
    analytic bubble_fraction / inflight_microbatches terms the cost
    model charges."""
    sim = get_schedule(sched).simulate(P_, M)
    assert sim["bubble"] == pytest.approx(bubble_fraction(P_, M, sched))
    assert sim["peak_inflight"] == inflight_microbatches(P_, M, sched)


def test_schedule_grammar():
    """'1f1b_i<v>' parses as v virtual stages per rank; 'zb' is a known
    one-chunk schedule; junk and v=1 are rejected with ValueError."""
    assert parse_schedule("zb") == ("zb", 1)
    assert parse_schedule("1f1b_i2")[1] == 2
    assert virtual_stages("1f1b_i4") == 4
    assert virtual_stages("gpipe") == 1 and virtual_stages("zb") == 1
    assert known_schedule("1f1b_i7") and known_schedule("zb")
    assert not known_schedule("interleaved") and not known_schedule("1f1b_i1")
    with pytest.raises(ValueError):
        parse_schedule("1f1b_i1")     # v == 1 is plain 1f1b
    with pytest.raises(ValueError):
        parse_schedule("zb_i2")


def test_frontier_schedule_rejections():
    with pytest.raises(ValueError):
        get_schedule("1f1b_i2").tick_table(4, 6)   # M % P != 0
    with pytest.raises(ValueError):
        get_schedule("zb").tick_table(4, 2)        # M < P


def test_zb_op_tick_counts():
    """zb splits every backward into dgrad (B) + wgrad (W) sub-ticks:
    P*M of each op, and the total tick span is 3M + 2(P-1)."""
    c = op_tick_counts("zb", 4, 8)
    assert c["F"] == c["B"] == c["W"] == 32
    assert c["ticks"] == 3 * 8 + 2 * (4 - 1)
    c1 = op_tick_counts("1f1b", 4, 8)
    assert c1["W"] == 0 and c1["F"] == c1["B"] == 32
    ci = op_tick_counts("1f1b_i2", 4, 8)
    assert ci["W"] == 0 and ci["F"] == ci["B"] == 64   # per-chunk ticks


@settings(max_examples=40, deadline=None)
@given(P_=st.integers(2, 5), k=st.integers(1, 5), v=st.integers(2, 3))
def test_property_interleaved_bubble_formula_vs_simulation(P_, k, v):
    """ISSUE 10 satellite: for every (P, M = kP, v) the interleaved
    bubble formula (P-1)/(vM+P-1) equals the tick-count simulation —
    the v-times-finer warmup ramp is exactly what the table emits."""
    M = P_ * k
    sim = get_schedule(f"1f1b_i{v}").simulate(P_, M)
    assert sim["bubble"] == pytest.approx((P_ - 1) / (v * M + P_ - 1))
    assert sim["bubble"] < bubble_fraction(P_, M, "1f1b")


@settings(max_examples=40, deadline=None)
@given(P_=st.integers(2, 6), extra=st.integers(0, 16))
def test_property_zb_bubble_and_inflight_vs_1f1b(P_, extra):
    """ISSUE 10 satellite: zb's simulated bubble matches
    2(P-1)/(3M+2P-2), stays below 1F1B's, and its activation peak never
    exceeds 1F1B's min(M, P) cap (the dgrad sub-tick frees the
    activation; only the param-shaped wgrad stash persists)."""
    M = P_ + extra
    zb = get_schedule("zb").simulate(P_, M)
    fb = get_schedule("1f1b").simulate(P_, M)
    assert zb["bubble"] == pytest.approx(
        2 * (P_ - 1) / (3 * M + 2 * P_ - 2))
    assert zb["bubble"] < fb["bubble"]
    assert zb["peak_inflight"] <= fb["peak_inflight"]


@settings(max_examples=60, deadline=None)
@given(P_=st.integers(2, 6), extra=st.integers(0, 24))
def test_property_1f1b_bubble_formula_vs_simulation(P_, extra):
    """ISSUE 5 satellite: the 1F1B bubble formula equals the tick-count
    simulation for every (P, M >= P), and the simulated in-flight peak is
    exactly min(M, P)."""
    M = P_ + extra
    sim = get_schedule("1f1b").simulate(P_, M)
    assert sim["bubble"] == pytest.approx((P_ - 1) / (M + P_ - 1))
    assert sim["peak_inflight"] == min(M, P_)
    gsim = get_schedule("gpipe").simulate(P_, M)
    assert gsim["peak_inflight"] == M
    assert gsim["bubble"] == pytest.approx(sim["bubble"])


# ---------------------------------------------------------------------------
# 1F1B execution == sequential oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=4, d_model=128)
    rt = Runtime()
    key = jax.random.PRNGKey(0)
    layers = [_init_layer(cfg, i, k) for i, k in
              enumerate(jax.random.split(key, 4))]
    stacked = {"layers": _tree_stack(layers)}
    return cfg, rt, layers, stacked


def _sequential(cfg, rt, layers, x):
    M, mb, S, d = x.shape
    h = x.reshape(M * mb, S, d)
    for lp in layers:
        h, _, _ = _apply_layer(cfg, _sig(cfg, 0), lp, h, None, rt)
    return h.reshape(M, mb, S, d)


@pytest.mark.parametrize("mesh_axes", [("pipe",), ("pipe", "data")])
def test_1f1b_matches_sequential_fwd_and_grad(setup, eight_devices,
                                              mesh_axes):
    """The 1F1B custom_vjp (combined recompute-fwd/bwd tick loop) must
    agree with sequential application — including the composed
    (pipe, data) mesh and gradients w.r.t. params AND inputs."""
    cfg, rt, layers, stacked = setup
    if mesh_axes == ("pipe",):
        mesh = jax.make_mesh((4,), mesh_axes, devices=eight_devices[:4])
        batch_axes = ()
    else:
        mesh = jax.make_mesh((4, 2), mesh_axes, devices=eight_devices)
        batch_axes = ("data",)
    M, mb, S, d = 8, 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(0), (M, mb, S, d)) * 0.5
    stage_fn = make_pipelined_block_fn(cfg, rt)

    def pipelined(params, x):
        out, _aux = pipeline_apply(stage_fn, params, x, mesh, "pipe",
                                   batch_axes=batch_axes, schedule="1f1b")
        return out

    with use_mesh(mesh):
        out_p = jax.jit(pipelined)(stacked, x)
    out_s = _sequential(cfg, rt, layers, x)
    assert float(jnp.max(jnp.abs(out_p - out_s))) < 1e-4

    def loss_p(params, x):
        return jnp.sum(pipelined(params, x) ** 2)

    def loss_s(layers, x):
        return jnp.sum(_sequential(cfg, rt, layers, x) ** 2)

    with use_mesh(mesh):
        g_p, gx_p = jax.jit(jax.grad(loss_p, argnums=(0, 1)))(stacked, x)
    g_s_layers, gx_s = jax.grad(loss_s, argnums=(0, 1))(layers, x)
    g_s = {"layers": _tree_stack(g_s_layers)}
    errs = [float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(g_p), jax.tree.leaves(g_s))]
    assert max(errs) < 5e-3, max(errs)
    assert float(jnp.max(jnp.abs(gx_p - gx_s))) < 5e-3


def test_all_schedules_equal_gpipe_execution(setup, eight_devices):
    """Same work, different order: every registered schedule (plus an
    unregistered interleave depth) computes the identical function, so
    outputs and grads must agree with gpipe's — including the zb
    executor's split dgrad/wgrad backward and the interleaved
    non-contiguous stage chunking (L=4 % (P=2 * v=2) == 0)."""
    cfg, rt, layers, stacked = setup
    mesh = jax.make_mesh((2,), ("pipe",), devices=eight_devices[:2])
    M, mb, S, d = 4, 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, S, d)) * 0.5
    stage_fn = make_pipelined_block_fn(cfg, rt)

    outs, grads = {}, {}
    for sched in ("gpipe", "1f1b", "1f1b_i2", "zb"):
        def loss(params, sched=sched):
            out, _ = pipeline_apply(stage_fn, params, x, mesh, "pipe",
                                    schedule=sched)
            return jnp.sum(out ** 2)

        with use_mesh(mesh):
            outs[sched], grads[sched] = jax.jit(
                jax.value_and_grad(loss))(stacked)
    for sched in ("1f1b", "1f1b_i2", "zb"):
        assert float(outs["gpipe"]) == pytest.approx(float(outs[sched]),
                                                     rel=1e-5), sched
        errs = [float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(grads["gpipe"]),
                    jax.tree.leaves(grads[sched]))]
        assert max(errs) < 5e-3, (sched, max(errs))


@pytest.mark.parametrize("sched", ["1f1b_i2", "zb"])
def test_frontier_schedules_match_sequential_composed_mesh(
        setup, eight_devices, sched):
    """ISSUE 10 acceptance: the new executors must agree with sequential
    application on a composed (pipe, data) mesh — forward AND gradients
    w.r.t. params and inputs, with the interleaved param permutation
    un-permuting its cotangents."""
    cfg, rt, layers, stacked = setup
    mesh = jax.make_mesh((2, 2), ("pipe", "data"),
                         devices=eight_devices[:4])
    M, mb, S, d = 4, 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(3), (M, mb, S, d)) * 0.5
    stage_fn = make_pipelined_block_fn(cfg, rt)

    def pipelined(params, x):
        out, _aux = pipeline_apply(stage_fn, params, x, mesh, "pipe",
                                   batch_axes=("data",), schedule=sched)
        return out

    with use_mesh(mesh):
        out_p = jax.jit(pipelined)(stacked, x)
    out_s = _sequential(cfg, rt, layers, x)
    assert float(jnp.max(jnp.abs(out_p - out_s))) < 1e-4

    def loss_p(params, x):
        return jnp.sum(pipelined(params, x) ** 2)

    def loss_s(layers, x):
        return jnp.sum(_sequential(cfg, rt, layers, x) ** 2)

    with use_mesh(mesh):
        g_p, gx_p = jax.jit(jax.grad(loss_p, argnums=(0, 1)))(stacked, x)
    g_s_layers, gx_s = jax.grad(loss_s, argnums=(0, 1))(layers, x)
    g_s = {"layers": _tree_stack(g_s_layers)}
    errs = [float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(g_p), jax.tree.leaves(g_s))]
    assert max(errs) < 5e-3, max(errs)
    assert float(jnp.max(jnp.abs(gx_p - gx_s))) < 5e-3


def test_interleaved_apply_rejects_bad_chunking(setup, eight_devices):
    """L % (P*v) != 0 and M % P != 0 are construction errors, not silent
    truncation."""
    cfg, rt, layers, stacked = setup
    mesh = jax.make_mesh((4,), ("pipe",), devices=eight_devices[:4])
    stage_fn = make_pipelined_block_fn(cfg, rt)
    x = jnp.zeros((8, 2, 16, cfg.d_model))
    with pytest.raises(ValueError):       # 4 layers % (4 stages * 2) != 0
        with use_mesh(mesh):
            pipeline_apply(stage_fn, stacked, x, mesh, "pipe",
                           schedule="1f1b_i2")
    mesh2 = jax.make_mesh((2,), ("pipe",), devices=eight_devices[:2])
    x2 = jnp.zeros((3, 2, 16, cfg.d_model))
    with pytest.raises(ValueError):       # M=3 % P=2 != 0
        with use_mesh(mesh2):
            pipeline_apply(stage_fn, stacked, x2, mesh2, "pipe",
                           schedule="1f1b_i2")


def test_measured_memory_ordering_gpipe_vs_1f1b(setup, eight_devices):
    """ISSUE 10 satellite: the compiled executable's measured temp
    (activation/workspace) bytes must order the same way the cost
    model's in-flight term predicts — gpipe holds all M=8 microbatch
    activations, 1f1b caps at P=4."""
    cfg, rt, layers, stacked = setup
    mesh = jax.make_mesh((4,), ("pipe",), devices=eight_devices[:4])
    M, mb, S, d = 8, 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(4), (M, mb, S, d)) * 0.5
    stage_fn = make_pipelined_block_fn(cfg, rt)
    temp = {}
    for sched in ("gpipe", "1f1b"):
        def loss(params, sched=sched):
            out, _ = pipeline_apply(stage_fn, params, x, mesh, "pipe",
                                    schedule=sched)
            return jnp.sum(out ** 2)

        with use_mesh(mesh):
            compiled = jax.jit(jax.value_and_grad(loss)).lower(
                stacked).compile()
        ma = compiled.memory_analysis()
        if ma is None or not getattr(ma, "temp_size_in_bytes", 0):
            pytest.skip("backend reports no executable memory analysis")
        temp[sched] = int(ma.temp_size_in_bytes)
    assert inflight_microbatches(4, M, "1f1b") < \
        inflight_microbatches(4, M, "gpipe")
    assert temp["1f1b"] < temp["gpipe"], temp


def test_1f1b_apply_rejects_underfilled(setup, eight_devices):
    cfg, rt, layers, stacked = setup
    mesh = jax.make_mesh((4,), ("pipe",), devices=eight_devices[:4])
    x = jnp.zeros((2, 2, 16, cfg.d_model))       # M=2 < P=4
    stage_fn = make_pipelined_block_fn(cfg, rt)
    with pytest.raises(ValueError):
        with use_mesh(mesh):
            pipeline_apply(stage_fn, stacked, x, mesh, "pipe",
                           schedule="1f1b")


# ---------------------------------------------------------------------------
# probe reliability flag + batch-axis drop warning (ISSUE 5 satellites)
# ---------------------------------------------------------------------------

def test_measure_bubble_flags_unreliable_fit():
    """A non-increasing two-point fit (t(2M) <= t(M)) is a failed
    measurement, not a 0.0 bubble — the record must say so."""
    def step_for_m(m):
        delay = 0.03 if m == 4 else 0.01      # t2 < t1: noisy-host shape

        def run():
            time.sleep(delay)
            return jnp.zeros(())

        return run

    rec = measure_bubble_fraction(step_for_m, n_stages=2, microbatches=4,
                                  n_iter=1)
    assert rec["fit_unreliable"] is True
    assert rec["bubble_measured"] == 0.0      # the clamp is still reported

    def step_ok(m):
        delay = 0.01 * (m + 1)                # properly increasing in M

        def run():
            time.sleep(delay)
            return jnp.zeros(())

        return run

    rec = measure_bubble_fraction(step_ok, n_stages=2, microbatches=4,
                                  n_iter=1, sched="1f1b")
    assert rec["fit_unreliable"] is False
    assert rec["sched"] == "1f1b"
    assert rec["bubble_measured"] > 0.0


def test_measure_bubble_interleaved_matches_formula():
    """ISSUE 10 satellite: with a deterministic synthetic step whose
    wall time is exactly t_tick * (v*M + P-1), the interleaved fit must
    recover the (P-1)/(vM+P-1) bubble within the probe's 20% tolerance,
    and the record must carry the virtual-stage count."""
    P_, M, v, c = 2, 4, 2, 0.006

    def step_for_m(m):
        delay = c * (v * m + (P_ - 1))

        def run():
            time.sleep(delay)
            return jnp.zeros(())

        return run

    rec = measure_bubble_fraction(step_for_m, n_stages=P_, microbatches=M,
                                  n_iter=2, sched=f"1f1b_i{v}")
    assert rec["virtual_stages"] == v
    assert rec["bubble_predicted"] == pytest.approx(
        (P_ - 1) / (v * M + P_ - 1))
    assert rec["fit_unreliable"] is False
    assert rec["bubble_measured"] == pytest.approx(rec["bubble_predicted"],
                                                   rel=0.2)


def test_probe_records_virtual_stages_on_live_pipeline(eight_devices):
    """The real probe path (pipeline_apply lowering) threads the
    schedule through: an interleaved strategy's record carries v and the
    interleaved prediction, not plain 1F1B's."""
    from repro import strategy as strategy_lib
    from repro.perf.pipeline_probe import measure_bubble

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=4, d_model=64)
    rec = measure_bubble(cfg, strategy_lib.parse("fsdp_pp2_mb4_1f1b_i2"),
                         strategy_lib.host_topology(), seq_len=32, n_iter=1)
    assert rec["sched"] == "1f1b_i2"
    assert rec["virtual_stages"] == 2
    assert rec["bubble_predicted"] == pytest.approx(1 / 9)  # (P-1)/(vM+P-1)
    assert "fit_unreliable" in rec


def test_batch_axes_spec_warns_once_on_dropped_axis(eight_devices, caplog):
    """pp with microbatch rows that cannot occupy the data axis runs with
    replicated (redundant) data-parallel compute; that used to be fully
    silent — now it logs a warning, once per configuration."""
    import repro.core.pipeline as pl
    mesh = jax.make_mesh((2, 4), ("pipe", "data"), devices=eight_devices)
    pl._warned_dropped.clear()
    with caplog.at_level(logging.WARNING, logger="repro.core.pipeline"):
        kept = batch_axes_spec(mesh, ("data",), 3)      # 3 % 4 -> dropped
        assert kept == ()
        n1 = sum("replicated" in r.message for r in caplog.records)
        kept = batch_axes_spec(mesh, ("data",), 3)      # same config again
        n2 = sum("replicated" in r.message for r in caplog.records)
    assert n1 == 1 and n2 == 1                           # warned exactly once
    with caplog.at_level(logging.WARNING, logger="repro.core.pipeline"):
        caplog.clear()
        assert batch_axes_spec(mesh, ("data",), 8) == ("data",)
        assert not caplog.records                        # clean fit: silent


def test_probe_handles_pp_ep_strategy(eight_devices):
    """Regression: the bubble probe builds its stage runtime via the same
    recipe as the forward path (`transformer.pipeline_stage_runtime`), so
    a pp x ep strategy probes through the in-stage ep_manual dispatch
    instead of crashing on a nested shard_map — and its synthetic
    microbatch is rounded up to occupy the expert axis."""
    import dataclasses as dc
    from repro import strategy as strategy_lib
    from repro.configs import get_config
    from repro.perf.pipeline_probe import measure_bubble

    cfg = reduced(get_config("deepseek-moe-16b"), n_layers=4, d_model=128)
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, moe_start_layer=0))
    rec = measure_bubble(cfg, strategy_lib.parse("fsdp_pp2_ep2_mb2"),
                         strategy_lib.host_topology(), seq_len=32, n_iter=1)
    assert rec["pp"] == 2 and rec["sched"] == "gpipe"
    assert rec["probe_mb_rows"] % 4 == 0       # data2 x expert2 occupied
    assert rec["bubble_predicted"] == pytest.approx(1 / 3)
    assert "fit_unreliable" in rec


def test_schedule_registry():
    assert set(SCHEDULES) == {"gpipe", "1f1b", "1f1b_i2", "zb"}
    for name, sched in SCHEDULES.items():
        assert sched.name == name
        assert get_schedule(name) is sched
    # unregistered interleave depths resolve through the grammar
    assert get_schedule("1f1b_i3").name == "1f1b_i3"
