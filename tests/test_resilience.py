"""Fault-tolerance tier: fault injection, checkpoint integrity, elastic
restarts, and the goodput model.

The load-bearing guarantee is the kill/resume bit-match: a run crashed by
an injected ``SimulatedFailure`` and resumed by the supervisor from the
newest CRC-valid checkpoint must produce parameters bit-identical to an
uninterrupted run — params, optimizer state, and data-pipeline position
all restore exactly.  Around it: fault-plan determinism, atomic saves
(partial directories are invisible), corrupt-checkpoint fallback,
restart budget/backoff, the async checkpointer's bounded stall, and the
Young/Daly goodput model's monotonicity + the planner flip it causes.
"""
import dataclasses
import json
import os
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as hst

import jax
import jax.numpy as jnp

from repro import checkpointing as ckpt_lib
from repro import strategy as strategy_lib
from repro.configs import ShapeConfig, get_config, reduced
from repro.core import costmodel as cm
from repro.core import parallel as par
from repro.data.pipeline import Batcher, SyntheticSource
from repro.resilience import (FaultPlan, RestartBudgetExceeded,
                              SimulatedFailure, Supervisor, SupervisorConfig,
                              load_fault_plan)
from repro.resilience.supervisor import supervise_training
from repro.train.trainer import TrainConfig, train_loop


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_and_round_trips():
    a = FaultPlan.generate(7, 200, crash_rate=0.02, straggler_rate=0.05,
                           ckpt_io_rate=0.03)
    b = FaultPlan.generate(7, 200, crash_rate=0.02, straggler_rate=0.05,
                           ckpt_io_rate=0.03)
    assert a.events == b.events and a.events      # same seed -> same plan
    c = FaultPlan.generate(8, 200, crash_rate=0.02, straggler_rate=0.05,
                           ckpt_io_rate=0.03)
    assert a.events != c.events                   # seed matters
    # per-kind substreams: changing one rate must not reshuffle the others
    d = FaultPlan.generate(7, 200, crash_rate=0.02, straggler_rate=0.5,
                           ckpt_io_rate=0.03)
    assert a.crash_steps() == d.crash_steps()
    rt = FaultPlan.from_json(a.to_json())
    assert rt.events == a.events and rt.seed == a.seed


def test_fault_plan_injection_semantics(tmp_path):
    plan = load_fault_plan("crash@3,5")
    assert plan.crash_steps() == [3, 5]
    plan.check_crash(2)                           # nothing scheduled
    with pytest.raises(SimulatedFailure) as ei:
        plan.check_crash(3)
    assert ei.value.step == 3
    plan.check_crash(3)                           # fires once: resume passes
    # stragglers multiply, ckpt_io errors are transient (budget then ok)
    from repro.resilience.faults import FaultEvent
    plan2 = FaultPlan(events=[FaultEvent(1, "straggler", magnitude=3.0),
                              FaultEvent(2, "ckpt_io", magnitude=1.0)])
    assert plan2.delay_multiplier(1) == 3.0 and plan2.delay_multiplier(0) == 1.0
    with pytest.raises(ckpt_lib.CheckpointIOError):
        plan2.ckpt_io_check(2)
    plan2.ckpt_io_check(2)                        # budget spent: retry works
    # file round-trip through the CLI loader
    p = tmp_path / "plan.json"
    p.write_text(plan.to_json())
    assert load_fault_plan(str(p)).crash_steps() == [3, 5]


# ---------------------------------------------------------------------------
# checkpoint integrity
# ---------------------------------------------------------------------------

def _tree(seed=0, shape=(4, 3)):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, shape),
                       "b": jnp.zeros((shape[1],), jnp.bfloat16)},
            "opt": {"step": jnp.zeros((), jnp.int32)}}


def test_save_is_atomic_and_latest_skips_partial(tmp_path):
    d = str(tmp_path)
    ckpt_lib.save_checkpoint(d, 2, _tree())
    ckpt_lib.save_checkpoint(d, 4, _tree(1))
    # a partial save (dir present, no manifest — the pre-atomic failure
    # mode) must be invisible to discovery
    os.makedirs(os.path.join(d, "step_6"))
    np.save(os.path.join(d, "step_6", "orphan.npy"), np.zeros(3))
    # an interrupted tmp dir must be invisible too, and gc'd
    os.makedirs(os.path.join(d, "step_8.tmp-dead"))
    assert ckpt_lib.list_steps(d) == [2, 4]
    assert ckpt_lib.latest_step(d) == 4
    assert ckpt_lib.validate_checkpoint(d, 4) == []
    ckpt_lib.gc_checkpoints(d, keep=1)
    assert ckpt_lib.list_steps(d) == [4]
    assert not os.path.exists(os.path.join(d, "step_8.tmp-dead"))


def test_restore_reports_all_problems_in_one_error(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt_lib.save_checkpoint(d, 1, tree)
    target = {"params": {"w": tree["params"]["w"],
                         "b": jnp.zeros((5,), jnp.bfloat16),   # wrong shape
                         "extra": jnp.zeros((2,))},            # not in ckpt
              "opt": {"step": tree["opt"]["step"]}}
    with pytest.raises(ckpt_lib.CheckpointError) as ei:
        ckpt_lib.restore_checkpoint(d, 1, target)
    msg = str(ei.value)
    # one aggregated error names every offender: the missing leaf and the
    # mismatched leaf with both shapes
    assert "params/extra" in msg
    assert "params/b" in msg and "(3,)" in msg and "(5,)" in msg


def test_corrupt_checkpoint_detected_and_skipped(tmp_path):
    d = str(tmp_path)
    ckpt_lib.save_checkpoint(d, 1, _tree(0))
    ckpt_lib.save_checkpoint(d, 2, _tree(1))
    # flip one byte in the newest checkpoint's largest leaf (resolve the
    # file through the manifest rather than assuming the naming scheme)
    step_dir = os.path.join(d, "step_00000002")
    man = json.load(open(os.path.join(step_dir, "manifest.json")))
    wkey = [k for k in man["leaves"] if k.endswith("w")][0]
    leaf = os.path.join(step_dir, man["leaves"][wkey]["file"])
    raw = bytearray(open(leaf, "rb").read())
    raw[-1] ^= 0xFF
    open(leaf, "wb").write(bytes(raw))
    problems = ckpt_lib.validate_checkpoint(d, 2)
    assert problems and any("crc" in p.lower() for p in problems)
    # unverified discovery still sees it; verified discovery falls back
    assert ckpt_lib.latest_valid_step(d, verify=False) == 2
    assert ckpt_lib.latest_valid_step(d, verify=True) == 1
    with pytest.raises(ckpt_lib.CheckpointError):
        ckpt_lib.restore_checkpoint(d, 2, _tree(1), verify=True)
    # the supervisor's restore point is the CRC-valid one
    sup = Supervisor(SupervisorConfig(), ckpt_dir=d)
    assert sup.restore_step() == 1


def test_async_checkpointer_bit_equal_bounded_and_fast(tmp_path):
    tree = _tree(3, shape=(64, 64))
    sync_dir, async_dir = str(tmp_path / "sync"), str(tmp_path / "async")
    t0 = time.perf_counter()
    ckpt_lib.save_checkpoint(sync_dir, 1, tree)
    t_sync = time.perf_counter() - t0

    in_flight, seen = [], []
    gate = threading.Event()

    def hook(step):
        in_flight.append(step)
        seen.append(len(in_flight))
        gate.wait(5.0)
        in_flight.remove(step)

    with ckpt_lib.AsyncCheckpointer(async_dir, max_in_flight=2,
                                    io_error_hook=hook) as ck:
        stall = ck.save(1, tree)
        ck.save(2, tree)
        t0 = time.perf_counter()
        gate.set()                    # 3rd save blocks until a slot frees
        ck.save(3, tree)
        ck.wait()
    # bounded in-flight: the hook never observed more than max_in_flight
    assert max(seen) <= 2
    # on-thread stall is the snapshot only — well under the full write
    assert stall < t_sync * 0.9
    # async result bit-matches the sync writer's
    a = ckpt_lib.restore_checkpoint(sync_dir, 1, _tree(99, (64, 64)))
    b = ckpt_lib.restore_checkpoint(async_dir, 1, _tree(98, (64, 64)))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_async_checkpointer_surfaces_background_errors(tmp_path):
    def hook(step):
        raise ckpt_lib.CheckpointIOError(f"disk on fire at {step}")

    ck = ckpt_lib.AsyncCheckpointer(str(tmp_path), io_error_hook=hook)
    ck.save(1, _tree())
    with pytest.raises(ckpt_lib.CheckpointIOError):
        ck.wait()
    ck.close()


# ---------------------------------------------------------------------------
# kill / resume / supervisor
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return reduced(get_config("qwen3-0.6b"), n_layers=2, d_model=64)


def _setup(cfg, spec="fsdp"):
    shape = ShapeConfig("res", 16, 4, "train")
    strat = strategy_lib.parse(spec)
    topo = strategy_lib.host_topology()
    plan = strat.to_plan(cfg, topo, shape)
    rt = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                          compute_dtype=jnp.float32)
    return shape, strat, topo, plan, rt


def _make_batches(cfg):
    return Batcher(SyntheticSource(cfg.vocab_size, seed=7), 16, 4)


RT_F32 = dict(param_dtype=jnp.float32, compute_dtype=jnp.float32)


def test_batcher_position_restores_stream():
    cfg = _tiny_cfg()
    full = _make_batches(cfg)
    it = iter(full)
    skipped = [next(it) for _ in range(5)][3:]
    resumed = iter(_make_batches(cfg).at(3))
    for want in skipped:
        got = next(resumed)
        assert np.array_equal(want["tokens"], got["tokens"])
        assert np.array_equal(want["labels"], got["labels"])


def test_killed_and_resumed_run_bitmatches_uninterrupted(tmp_path):
    """The tentpole guarantee.  Run A trains 6 steps uninterrupted.  Run B
    checkpoints every 2 steps, crashes at step 4 via an injected fault,
    and is resumed by the supervisor from the newest valid checkpoint —
    params must be bit-identical, and the event log must show exactly one
    recovered failure."""
    cfg = _tiny_cfg()
    shape, strat, topo, plan, rt = _setup(cfg)
    key = jax.random.PRNGKey(0)

    tc_a = TrainConfig(steps=6, warmup=1, log_every=100)
    p_a, _, _ = train_loop(cfg, plan, rt, tc_a, _make_batches(cfg), key=key)

    log = str(tmp_path / "events.json")
    tc_b = TrainConfig(steps=6, warmup=1, log_every=100, ckpt_every=2,
                       ckpt_dir=str(tmp_path / "ckpt"))
    p_b, _, _, sup = supervise_training(
        cfg, strat, topo, shape, tc_b, lambda: _make_batches(cfg),
        rt_overrides=RT_F32, key=key, fault_plan=FaultPlan.crashes_at(4),
        sup_cfg=SupervisorConfig(backoff_base_s=0.0, event_log_path=log))

    for a, b in zip(jax.tree.leaves(jax.device_get(p_a)),
                    jax.tree.leaves(jax.device_get(p_b))):
        assert np.array_equal(a, b)
    events = json.load(open(log))
    assert events["n_failures"] == 1
    fail = [e for e in events["events"] if e["kind"] == "failure"][0]
    assert fail["simulated"] and fail["step_failed"] == 4
    assert fail["restore_step"] is not None


def test_trainer_retries_transient_ckpt_io_faults(tmp_path):
    cfg = _tiny_cfg()
    shape, strat, topo, plan, rt = _setup(cfg)
    from repro.resilience.faults import FaultEvent
    plan_f = FaultPlan(events=[FaultEvent(2, "ckpt_io", magnitude=1.0)])
    tc = TrainConfig(steps=4, warmup=1, log_every=100, ckpt_every=2,
                     ckpt_dir=str(tmp_path))
    train_loop(cfg, plan, rt, tc, _make_batches(cfg),
               key=jax.random.PRNGKey(0), fault_plan=plan_f)
    # both saves landed despite the injected transient failure at step 2
    assert ckpt_lib.list_steps(str(tmp_path)) == [2, 4]


def test_supervisor_backoff_and_budget_exhaustion(tmp_path):
    log = str(tmp_path / "events.json")
    sup = Supervisor(SupervisorConfig(max_restarts=2, backoff_base_s=0.01,
                                      backoff_factor=2.0, backoff_max_s=0.02,
                                      event_log_path=log))
    assert [sup.backoff_s(i) for i in range(3)] == [0.01, 0.02, 0.02]

    calls = []

    def attempt(n, strat, topo):
        calls.append(n)
        raise SimulatedFailure(step=5 + n)

    with pytest.raises(RestartBudgetExceeded) as ei:
        sup.run(attempt)
    assert calls == [0, 1, 2]            # initial try + 2 restarts
    assert isinstance(ei.value.__cause__, SimulatedFailure)
    events = json.load(open(log))
    assert events["n_failures"] == 3
    assert events["events"][-1]["budget_exhausted"]


def test_supervisor_replans_for_degraded_devices():
    """A crash reporting lost devices shrinks the topology; the planner
    re-picks a strategy that still lowers on the survivors."""
    cfg = _tiny_cfg()
    shape = ShapeConfig("res", 16, 4, "train")
    topo = strategy_lib.host_topology()
    strat = strategy_lib.parse("fsdp")
    sup = Supervisor(SupervisorConfig(max_restarts=2, backoff_base_s=0.0))
    seen = []

    def attempt(n, s, t):
        seen.append((n, t.n_devices, s.format()))
        if n == 0:
            raise SimulatedFailure(step=1, lost_devices=4)
        return "ok"

    out = sup.run(attempt, strategy=strat, topology=topo, cfg=cfg,
                  shape=shape)
    assert out == "ok"
    assert seen[0][1] == topo.n_devices
    assert seen[1][1] == topo.n_devices - 4      # replanned onto survivors
    replans = [e for e in sup.events if e["kind"] == "replan"]
    assert replans and replans[0]["n_devices"] == topo.n_devices - 4


def test_supervised_training_survives_repeated_crashes(tmp_path):
    """Multiple crashes across attempts, async checkpointing on — still
    bit-matches the uninterrupted run."""
    cfg = _tiny_cfg()
    shape, strat, topo, plan, rt = _setup(cfg)
    key = jax.random.PRNGKey(0)
    tc_a = TrainConfig(steps=5, warmup=1, log_every=100)
    p_a, _, _ = train_loop(cfg, plan, rt, tc_a, _make_batches(cfg), key=key)

    tc_b = TrainConfig(steps=5, warmup=1, log_every=100, ckpt_every=1,
                       ckpt_dir=str(tmp_path), ckpt_async=True, ckpt_keep=2)
    p_b, _, _, sup = supervise_training(
        cfg, strat, topo, shape, tc_b, lambda: _make_batches(cfg),
        rt_overrides=RT_F32, key=key, fault_plan=FaultPlan.crashes_at(2, 4),
        sup_cfg=SupervisorConfig(backoff_base_s=0.0))
    assert sum(e["kind"] == "failure" for e in sup.events) == 2
    for a, b in zip(jax.tree.leaves(jax.device_get(p_a)),
                    jax.tree.leaves(jax.device_get(p_b))):
        assert np.array_equal(a, b)
    # ckpt_keep pruned the directory
    assert len(ckpt_lib.list_steps(str(tmp_path))) <= 2


# ---------------------------------------------------------------------------
# goodput model + planner objective
# ---------------------------------------------------------------------------

def test_goodput_model_basics():
    hw = cm.HARDWARE["H100"]
    cfg = get_config("llama2-7b")
    # system MTBF shrinks linearly; goodput at sane defaults is ~1 small
    assert cm.system_mtbf(hw, 1000) == pytest.approx(hw.mtbf / 1000)
    s_small = cm.Strategy(8)
    r = cm.step_time(cfg, hw, s_small, 256, 4096)
    assert 0.99 < r.goodput_frac <= 1.0
    assert r.effective_wps == pytest.approx(r.wps * r.goodput_frac)
    assert r.ckpt_interval >= r.t_ckpt > 0
    # strategy-aware writers: HSDP (island-local shards) writes slower
    # than full FSDP at the same scale
    full = cm.Strategy(2048)
    hsdp = cm.Strategy(2048, fsdp_group=8)
    assert cm.distinct_writers(full) == 2048
    assert cm.distinct_writers(hsdp) == 8
    assert cm.checkpoint_write_time(cfg, hw, hsdp) > \
        cm.checkpoint_write_time(cfg, hw, full)
    # decode reports carry the no-failure identity
    rd = cm.decode_step_time(cfg, hw, cm.Strategy(8), 8, 2048)
    assert rd.goodput_frac == 1.0 and rd.effective_wps == rd.wps


@settings(max_examples=50, deadline=None)
@given(mtbf=hst.floats(1e4, 1e9), t_ckpt=hst.floats(1e-3, 100.0),
       factor=hst.floats(1.5, 16.0))
def test_goodput_monotone_in_failure_rate(mtbf, t_ckpt, factor):
    """More failures (lower system MTBF — linearly more devices) can
    never increase goodput."""
    g_better = cm.goodput(t_ckpt, mtbf * factor)
    g_worse = cm.goodput(t_ckpt, mtbf)
    assert g_worse <= g_better + 1e-12


@settings(max_examples=50, deadline=None)
@given(mtbf=hst.floats(1e4, 1e9), t_ckpt=hst.floats(1e-3, 100.0),
       tau_scale=hst.floats(0.05, 20.0))
def test_young_daly_interval_is_optimal(mtbf, t_ckpt, tau_scale):
    """No other checkpoint interval beats tau* = sqrt(2 * t_ckpt * M)."""
    tau_star = cm.young_daly_interval(t_ckpt, mtbf)
    g_star = cm.goodput(t_ckpt, mtbf, interval=tau_star)
    g_other = cm.goodput(t_ckpt, mtbf, interval=tau_star * tau_scale)
    assert g_other <= g_star + 1e-9


def test_planner_flips_between_wps_and_effective_wps():
    """The pinned failure-aware planning decision: at 2048 H100s with a
    pessimistic per-device MTBF, raw-throughput planning picks HSDP
    (cheap cross-island collectives, but only 8 island-local checkpoint
    writers) while goodput-aware planning picks a full-FSDP strategy
    whose n-way checkpoint writes keep the Young/Daly tax low."""
    cfg = get_config("llama2-7b")
    shape = ShapeConfig("flip", 4096, 1024, "train")
    hw = dataclasses.replace(cm.HARDWARE["H100"], mtbf=3e6)
    topo = strategy_lib.Topology("flip", 2048, 8, hardware="H100",
                                 hbm=80e9, hw_obj=hw)
    modes = ("hsdp", "fsdp")
    # pin the pre-overlap sweep: the ZeRO gather-prefetch token (ISSUE 10)
    # hides the FSDP gather cost outright, making one fsdp+ovl point win
    # BOTH objectives — this test pins the checkpoint-writer flip, which
    # lives in the overlap-free space
    kw = dict(dp_modes=modes, overlaps=(False,))
    a = strategy_lib.best(cfg, topo, shape, objective="wps", **kw)
    b = strategy_lib.best(cfg, topo, shape, objective="effective_wps", **kw)
    assert a.spec != b.spec
    assert a.spec.startswith("hsdp") and b.spec.startswith("fsdp")
    assert b.report.goodput_frac > a.report.goodput_frac
    assert b.report.effective_wps > a.report.effective_wps
    # and the objective is exposed through the public registry
    assert "effective_wps" in strategy_lib.OBJECTIVES
