"""Prefill + decode over caches must match the teacher-forced forward pass,
for every architecture family (attn full/SWA, GQA, RWKV-6 state, Mamba state,
hybrid interleave, MoE)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models import (Runtime, decode_step, forward, init_params, prefill)

RT = Runtime(rwkv_chunk=8, mamba_chunk=8, moe_impl="dense")


def _batch(cfg, key, B, S):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.1
    if cfg.input_mode == "tokens+vision":
        batch["vision_embeds"] = (
            jax.random.normal(key, (B, cfg.vision_tokens, cfg.d_model)) * 0.02)
    return batch


@pytest.mark.parametrize("arch", list_archs(assigned_only=True))
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)
    # S0 must exceed vision_tokens (16 in reduced VLM configs) so that the
    # decoded positions are text, not patches
    B, S, n_dec = 2, 24, 3
    batch = _batch(cfg, key, B, S)
    full_logits, _, _ = forward(cfg, params, batch, RT)

    S0 = S - n_dec
    pre = {k: (v[:, :S0] if k in ("tokens", "embeds") else v)
           for k, v in batch.items()}
    _, cache = prefill(cfg, params, pre, RT, max_len=S)

    for t in range(S0, S):
        extra = None
        if cfg.input_mode == "embeddings":
            extra = {"embeds": batch["embeds"][:, t:t + 1]}
        logits, cache = decode_step(
            cfg, params, cache, batch["tokens"][:, t:t + 1],
            jnp.asarray(t, jnp.int32), RT, extra=extra)
        err = jnp.max(jnp.abs(logits[:, 0] - full_logits[:, t]))
        assert err < 2e-3, (arch, t, float(err))


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b"])
def test_swa_ring_buffer_wraps(arch):
    """Decode past the window size must keep matching full attention output
    computed with the same window."""
    cfg = reduced(get_config(arch))         # window clamped to 64 in reduced
    cfg_small = cfg
    key = jax.random.PRNGKey(3)
    params = init_params(cfg_small, key)
    B, S = 1, 96                            # exceeds reduced window
    assert cfg_small.sliding_window and S > cfg_small.sliding_window
    batch = _batch(cfg_small, key, B, S)
    full_logits, _, _ = forward(cfg_small, params, batch, RT)

    S0 = 8
    pre = {"tokens": batch["tokens"][:, :S0]}
    _, cache = prefill(cfg_small, params, pre, RT, max_len=S)
    for t in range(S0, S):
        logits, cache = decode_step(
            cfg_small, params, cache, batch["tokens"][:, t:t + 1],
            jnp.asarray(t, jnp.int32), RT)
        err = jnp.max(jnp.abs(logits[:, 0] - full_logits[:, t]))
        assert err < 2e-3, (t, float(err))
