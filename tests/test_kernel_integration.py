"""End-to-end: forward pass and full train step with
Runtime(attn_impl='pallas', norm_impl='pallas') (Pallas kernels in
interpret mode) match / run against the pure-jnp path — the proof that
training differentiates through the kernel custom_vjps."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.launch.specs import concrete_train_batch
from repro.models import Runtime, forward, init_params


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "h2o-danube-1.8b", "rwkv6-1.6b"])
def test_pallas_path_matches_jnp(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(5)
    params = init_params(cfg, key)
    batch = concrete_train_batch(cfg, 1, 128, key)
    rt_jnp = Runtime(rwkv_chunk=16, attn_min_chunked_len=4096)
    rt_pls = Runtime(rwkv_chunk=16, attn_impl="pallas")
    l1, _, _ = forward(cfg, params, batch, rt_jnp)
    l2, _, _ = forward(cfg, params, batch, rt_pls)
    err = float(jnp.max(jnp.abs(l1 - l2)))
    assert err < 5e-3, (arch, err)


def test_loss_grads_pallas_match_jnp():
    """jax.grad of the full model loss agrees between the kernel path
    (attention + norm custom_vjps) and the pure-jnp path."""
    from repro.models.transformer import loss_fn

    cfg = reduced(get_config("qwen3-0.6b"))
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)
    batch = concrete_train_batch(cfg, 1, 128, key)
    rt_jnp = Runtime(attn_min_chunked_len=4096)
    rt_pls = Runtime(attn_impl="pallas", norm_impl="pallas")
    (l1, _), g1 = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, rt_jnp), has_aux=True)(params)
    (l2, _), g2 = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, rt_pls), has_aux=True)(params)
    assert abs(float(l1) - float(l2)) < 5e-3
    flat1 = jax.tree.leaves(g1)
    flat2 = jax.tree.leaves(g2)
    worst = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(flat1, flat2))
    assert worst < 5e-2, worst


def test_train_step_pallas_smoke():
    """make_train_step runs end-to-end on the Pallas kernel path and takes
    a finite optimizer step."""
    from repro.optim import init_opt_state
    from repro.train.trainer import TrainConfig, make_train_step

    cfg = reduced(get_config("qwen3-0.6b"))
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    opt_state = init_opt_state(params)
    batch = concrete_train_batch(cfg, 2, 128, key)
    rt = Runtime(attn_impl="pallas", norm_impl="pallas")
    step = jax.jit(make_train_step(cfg, rt, TrainConfig(steps=2)))
    params2, opt_state, m1 = step(params, opt_state, batch)
    _, _, m2 = step(params2, opt_state, batch)
    assert jnp.isfinite(m1["loss"]) and jnp.isfinite(m2["loss"])
    # the optimizer actually moved the weights
    moved = any(bool(jnp.any(a != b)) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved
    assert float(m2["loss"]) <= float(m1["loss"]) + 1.0
