"""End-to-end: forward pass with Runtime(attn_impl='pallas') (Pallas
kernels in interpret mode) matches the pure-jnp path."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.launch.specs import concrete_train_batch
from repro.models import Runtime, forward, init_params


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "h2o-danube-1.8b", "rwkv6-1.6b"])
def test_pallas_path_matches_jnp(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(5)
    params = init_params(cfg, key)
    batch = concrete_train_batch(cfg, 1, 128, key)
    rt_jnp = Runtime(rwkv_chunk=16, attn_min_chunked_len=4096)
    rt_pls = Runtime(rwkv_chunk=16, attn_impl="pallas")
    l1, _, _ = forward(cfg, params, batch, rt_jnp)
    l2, _, _ = forward(cfg, params, batch, rt_pls)
    err = float(jnp.max(jnp.abs(l1 - l2)))
    assert err < 5e-3, (arch, err)
