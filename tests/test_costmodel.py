"""Cost-model unit + property tests (hypothesis): structural invariants of
the paper's analytical model, plus the calibrated paper-claim anchors."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ShapeConfig
from repro.configs.llama2 import LLAMA2_7B
from repro.configs import get_config
from repro.core import costmodel as cm
from repro.strategy import Topology, search

HWS = [cm.V100, cm.A100, cm.H100, cm.TPU_V5E]
LLAMA2_70B = get_config("llama2-70b")


# ---------------------------------------------------------------------------
# collective properties
# ---------------------------------------------------------------------------

@given(b=st.floats(1e3, 1e10), n1=st.integers(2, 4096), n2=st.integers(2, 4096),
       hw=st.sampled_from(HWS))
@settings(max_examples=200, deadline=None)
def test_allgather_monotone_in_group_size(b, n1, n2, hw):
    lo, hi = sorted((n1, n2))
    assert cm.t_all_gather(hw, b, lo) <= cm.t_all_gather(hw, b, hi) + 1e-12


@given(b1=st.floats(1e3, 1e10), b2=st.floats(1e3, 1e10),
       n=st.integers(2, 4096), hw=st.sampled_from(HWS))
@settings(max_examples=200, deadline=None)
def test_collectives_monotone_in_bytes(b1, b2, n, hw):
    lo, hi = sorted((b1, b2))
    for f in (cm.t_all_gather, cm.t_all_reduce, cm.t_all_to_all):
        assert f(hw, lo, n) <= f(hw, hi, n) + 1e-12


@given(n=st.integers(2, 2048), hw=st.sampled_from(HWS))
@settings(max_examples=100, deadline=None)
def test_allgather_busbw_degrades_at_scale(n, hw):
    """Fig 2b: ring busbw at fixed message size never improves with n."""
    b = 256e6
    bw_n = cm.bus_bandwidth_allgather(hw, b, n)
    bw_2n = cm.bus_bandwidth_allgather(hw, b, 2 * n)
    assert bw_2n <= bw_n * 1.01


def test_tree_allreduce_scales_better_than_ring_allgather():
    """Fig 2a vs 2b: at large world size, NCCL tree AR keeps busbw while
    ring AG collapses."""
    b = 512e6
    ar_small = cm.bus_bandwidth_allreduce(cm.H100, b, 32)
    ar_big = cm.bus_bandwidth_allreduce(cm.H100, b, 2048)
    ag_small = cm.bus_bandwidth_allgather(cm.H100, b, 32)
    ag_big = cm.bus_bandwidth_allgather(cm.H100, b, 2048)
    assert ar_big / ar_small > ag_big / ag_small


# ---------------------------------------------------------------------------
# step model properties
# ---------------------------------------------------------------------------

@given(n=st.sampled_from([8, 32, 128, 512, 2048]),
       tp=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_step_report_sane(n, tp):
    if n % tp:
        return
    r = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(n, tp=tp, zero_stage=2),
                     global_batch=2 * n, seq_len=4096)
    assert r.t_step > 0 and r.t_step >= r.t_compute
    assert 0 <= r.mfu <= 1
    assert cm.H100.power_idle <= r.power_per_device <= cm.H100.power_peak
    assert r.t_comm_exposed <= r.t_step
    assert r.memory_per_device > 0


@given(n=st.sampled_from([64, 256, 1024]))
@settings(max_examples=20, deadline=None)
def test_weak_scaling_never_superlinear(n):
    """Per-device throughput cannot improve when adding devices (weak)."""
    r1 = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(n, zero_stage=2),
                      2 * n, 4096)
    r2 = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(2 * n, zero_stage=2),
                      4 * n, 4096)
    assert r2.wps_per_device <= r1.wps_per_device * 1.01


@given(pp=st.sampled_from([2, 4, 8]), extra=st.integers(0, 56),
       n=st.sampled_from([64, 256]))
@settings(max_examples=60, deadline=None)
def test_property_1f1b_memory_never_exceeds_gpipe(pp, extra, n):
    """ISSUE 5 satellite: for every M >= P the 1F1B activation term is
    <= GPipe's (in-flight microbatches min(M, P) vs M).  The memory win
    is not free: the executable 1F1B bakes remat into its backward, so
    the model charges it one extra forward pass — 1F1B is never cheaper
    in time, only in memory."""
    m = pp + extra
    kw = dict(n_devices=n, pp=pp, microbatches=m, zero_stage=2)
    r_g = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(**kw), n * 4, 4096)
    r_f = cm.step_time(LLAMA2_7B, cm.H100,
                       cm.Strategy(sched="1f1b", **kw), n * 4, 4096)
    assert r_f.memory_per_device <= r_g.memory_per_device + 1e-6
    assert r_f.t_step > r_g.t_step
    assert r_f.t_compute == pytest.approx(r_g.t_compute * (1 + 1 / 3))
    # equality exactly when the pipeline is minimally filled (M == P)
    if m == pp:
        assert r_f.memory_per_device == pytest.approx(r_g.memory_per_device)
    else:
        assert r_f.memory_per_device < r_g.memory_per_device


def test_sched_in_strategy_validity_and_row():
    assert not cm.Strategy(64, sched="zigzag").valid()
    assert not cm.Strategy(64, sched="1f1b").valid()      # pp == 1
    s = cm.Strategy(64, pp=2, microbatches=4, sched="1f1b")
    assert s.valid()
    r = cm.step_time(LLAMA2_7B, cm.H100, s, 256, 4096)
    assert r.row()["sched"] == "1f1b"
    # ISSUE 10: the schedule-frontier degrees are valid strategies too —
    # interleaving needs M % P == 0, overlap needs a sharded-param plan
    assert cm.Strategy(64, pp=2, microbatches=4, sched="1f1b_i2").valid()
    assert not cm.Strategy(64, pp=2, microbatches=5, sched="1f1b_i2").valid()
    assert not cm.Strategy(64, pp=2, microbatches=4, sched="1f1b_i1").valid()
    assert cm.Strategy(64, pp=2, microbatches=4, sched="zb").valid()
    assert cm.Strategy(64, zero_stage=3, overlap=True).valid()
    assert not cm.Strategy(64, zero_stage=0, overlap=True).valid()


def test_schedule_frontier_pinned_step_time():
    """ISSUE 10 acceptance (pinned): at a llama2-70b/H100 pp=4 point both
    interleaving (1f1b_i2: bubble (P-1)/(vM+P-1) for v x p2p volume) and
    zero-bubble (zb: bubble 2(P-1)/(3M+2P-2) for a param-shaped wgrad
    stash) beat plain 1F1B on modeled step time — while the cost model
    charges each its side of the trade rather than a free lunch."""
    kw = dict(n_devices=256, pp=4, microbatches=8, zero_stage=3)
    r = {sched: cm.step_time(LLAMA2_70B, cm.H100,
                             cm.Strategy(sched=sched, **kw), 256, 4096)
         for sched in ("1f1b", "1f1b_i2", "zb")}
    assert r["1f1b_i2"].t_step < r["1f1b"].t_step
    assert r["zb"].t_step < r["1f1b"].t_step
    # interleaving multiplies p2p hops: (pp*v - 1) / (pp - 1) = 7/3
    assert r["1f1b_i2"].comm_breakdown["pp_p2p"] == pytest.approx(
        r["1f1b"].comm_breakdown["pp_p2p"] * 7 / 3)
    # zb's bubble win is paid in memory: the stashed dgrad-deferred
    # weight-gradient state sits above 1F1B's activation footprint
    assert r["zb"].memory_per_device > r["1f1b"].memory_per_device
    # and both bubble terms are strictly below the 1F1B bubble
    P_, M = kw["pp"], kw["microbatches"]
    b_1f1b = (P_ - 1) / (M + P_ - 1)
    assert 2 * (P_ - 1) / (3 * M + 2 * P_ - 2) < b_1f1b
    assert (P_ - 1) / (2 * M + P_ - 1) < b_1f1b


def test_overlap_hides_exposed_fsdp_gathers():
    """The double-buffered ZeRO gather prefetch (overlap=True) widens the
    per-layer overlap window; on an FSDP-bound point the exposed gather
    time shrinks and step time strictly improves, while on compute-bound
    points it can only help, never hurt."""
    kw = dict(n_devices=1024, zero_stage=3, precision="bf16")
    r_off = cm.step_time(LLAMA2_70B, cm.A100,
                         cm.Strategy(**kw), 1024, 4096)
    r_on = cm.step_time(LLAMA2_70B, cm.A100,
                        cm.Strategy(overlap=True, **kw), 1024, 4096)
    assert r_on.t_step < r_off.t_step
    assert r_on.memory_per_device == pytest.approx(r_off.memory_per_device)


def test_memory_decreases_with_sharding():
    base = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(64, zero_stage=0),
                        128, 4096)
    z3 = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(64, zero_stage=3),
                      128, 4096)
    assert z3.memory_per_device < base.memory_per_device


# ---------------------------------------------------------------------------
# calibrated paper anchors (§4): model within tolerance of reported numbers
# ---------------------------------------------------------------------------

def test_claim_weak_scaling_throughput_drop():
    r128 = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(128, zero_stage=2),
                        256, 4096)
    r2048 = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(2048, zero_stage=2),
                         4096, 4096)
    drop = 1 - r2048.tflops_per_device / r128.tflops_per_device
    assert 0.30 < drop < 0.48, drop          # paper: 37.22%


def test_claim_power_nearly_flat():
    r128 = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(128, zero_stage=2),
                        256, 4096)
    r2048 = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(2048, zero_stage=2),
                         4096, 4096)
    pdrop = 1 - r2048.power_per_device / r128.power_per_device
    assert 0.02 < pdrop < 0.10, pdrop        # paper: 5.87%


def test_claim_tp_beats_fsdp_at_2048():
    base = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(2048, zero_stage=2),
                        4096, 4096)
    gains = [cm.step_time(LLAMA2_7B, cm.H100,
                          cm.Strategy(2048, tp=tp, zero_stage=2),
                          4096, 4096).wps / base.wps - 1 for tp in (2, 4)]
    assert max(gains) > 0.35, gains          # paper: +52.6%


def _best_report(hw):
    """Planner-ranked best (wps) on 256 chips of ``hw`` — the migrated
    form of the deleted ``sweep_strategies``/``best_strategy`` shims."""
    topo = Topology(hw.name, 256, island=hw.island, hardware=hw.name,
                    hbm=80e9, hw_obj=hw)
    shape = ShapeConfig("s", 4096, 512, "train")
    ranked = search(LLAMA2_7B, topo, shape, dp_modes=("fsdp",),
                    zero_stages=(2,), pps=(1, 2, 4, 8, 16), cps=(1,),
                    require_fits=False, require_lowerable=False)
    return ranked[0].report


def test_claim_hw_generation_mfu_gap():
    bh = _best_report(cm.H100)
    ba = _best_report(cm.A100)
    assert ba.mfu > bh.mfu                   # paper: 59.67% vs 40.77%
    assert 0.35 < bh.mfu < 0.50
    assert 0.52 < ba.mfu < 0.66


def test_claim_fsdp_comm_bound_beyond_128():
    exp = {n: cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(n, zero_stage=2),
                           2 * n, 4096).t_comm_exposed
           for n in (8, 128, 1024, 2048)}
    assert exp[8] < 1e-3                     # hidden at node scale
    # paper §5: exposure "unavoidable at scales *larger than* 128 GPUs".
    # The calibrated model places the latency-bound knee at ~1024 GPUs
    # (concentrating the measured 128->2048 throughput drop there) — a
    # documented calibration residual (EXPERIMENTS.md §Paper-claims).
    assert exp[2048] > exp[1024] > 0
    assert exp[128] <= exp[1024]


def test_claim_context_length_improves_overlap():
    """Fig 9: longer sequences -> larger compute kernels -> less exposure."""
    short = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(512, zero_stage=2),
                         1024, 2048)
    long = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(512, zero_stage=2),
                        1024, 8192)
    assert long.t_comm_exposed / long.t_step < short.t_comm_exposed / short.t_step
    assert long.mfu > short.mfu
