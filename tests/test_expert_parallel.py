"""Expert-parallelism tier (ISSUE 4): `fsdp_ep<k>` specs lowered through
Strategy.to_plan must produce the same loss/grads/updated params as the
dense-oracle and non-EP baselines (8-virtual-device conftest mesh), the
dispatch must actually lower to an all-to-all over the 'expert' axis, the
cost model must consume `strat.ep` (the old min(tp*pp, E) proxy is gone),
and on a node-bandwidth-constrained topology the planner's Pareto front
must place ep > 1 ahead of pure FSDP for deepseek-moe-16b — the MoE
analogue of PR 3's PP-vs-FSDP crossover test."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import pytest

from repro import strategy as strategy_lib
from repro.configs import ShapeConfig, get_config, reduced
from repro.configs.llama2 import LLAMA2_7B
from repro.core import costmodel as cm
from repro.core import parallel as par
from repro.launch.specs import concrete_train_batch
from repro.models import moe as moe_lib
from repro.models import transformer as tfm
from repro.models.layers import Runtime
from repro.optim import init_opt_state
from repro.strategy import Topology, pareto_front, search
from repro.train.trainer import (TrainConfig, make_train_step,
                                 place_train_state)

TOL = 5e-3
DEEPSEEK = get_config("deepseek-moe-16b")


def _tiny_moe_cfg(**moe_overrides):
    """Reduced deepseek-moe (4 experts, layer 0 dense) with ample capacity
    so dropping/EP dispatch drops nothing and the dense oracle is exact."""
    cfg = reduced(DEEPSEEK)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0, **moe_overrides))
    return cfg


def _train_metrics(cfg, rt, params, batch, plan=None):
    tc = TrainConfig()
    step = make_train_step(cfg, rt, tc)
    opt = init_opt_state(params)
    if plan is None:
        return step(params, opt, batch)
    with par.use_mesh(plan.mesh):
        params_s, opt_s, batch_s, pshard, _ = place_train_state(
            cfg, plan, params, opt, batch)
        return jax.jit(step, out_shardings=(pshard, None, None))(
            params_s, opt_s, batch_s)


@pytest.mark.parametrize("spec", ["fsdp_ep2", "fsdp_ep4", "fsdp_tp2_ep2"])
def test_ep_matches_dense_oracle(eight_devices, spec):
    """dense vs dropping vs ep2/ep4: fwd loss + grads + updated params of
    the full model agree across dispatch implementations."""
    cfg = _tiny_moe_cfg()
    shape = ShapeConfig("eq", 32, 8, "train")
    topo = strategy_lib.host_topology()
    strat = strategy_lib.parse(spec)
    plan = strat.to_plan(cfg, topo, shape)
    assert plan.expert == "expert" and plan.ep_size == strat.ep

    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = concrete_train_batch(cfg, 8, 32, key)

    rt_dense = Runtime(moe_impl="dense", attn_min_chunked_len=64)
    p1, _, m1 = _train_metrics(cfg, rt_dense, params, batch)

    rt_drop = Runtime(moe_impl="dropping", moe_groups=1,
                      attn_min_chunked_len=64)
    _, _, m_drop = _train_metrics(cfg, rt_drop, params, batch)

    rt_ep = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                             compute_dtype=jnp.float32, remat=False,
                             attn_min_chunked_len=64)
    assert rt_ep.moe_impl == "ep" and rt_ep.expert_axis == "expert"
    p2, _, m2 = _train_metrics(cfg, rt_ep, params, batch, plan)

    for m_other, label in ((m_drop, "dropping"), (m2, "ep")):
        dl = abs(float(m1["loss"]) - float(m_other["loss"]))
        assert dl < TOL, (spec, label, dl)
    rel_g = abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) \
        / max(float(m1["grad_norm"]), 1e-6)
    assert rel_g < TOL, (spec, rel_g)
    dp = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert dp < 5e-2, (spec, dp)


def test_ep_aux_loss_matches_oracle_exactly(eight_devices):
    """The load-balance aux loss is psum-reduced across expert shards —
    it must equal the dense oracle's global-batch value exactly (the EP
    router sees global counts, not a per-shard approximation)."""
    cfg = _tiny_moe_cfg()
    shape = ShapeConfig("eq", 16, 8, "train")
    topo = strategy_lib.host_topology()
    plan = strategy_lib.parse("fsdp_ep4").to_plan(cfg, topo, shape)
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
    _, aux0 = moe_lib.apply_moe(cfg, p, x, Runtime(moe_impl="dense"))
    rt = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                          compute_dtype=jnp.float32)
    with par.use_mesh(plan.mesh):
        _, aux_ep = jax.jit(lambda p: moe_lib.apply_moe(cfg, p, x, rt))(p)
    assert abs(float(aux0) - float(aux_ep)) < 1e-6


def test_ep_lowers_to_all_to_all(eight_devices):
    """The dispatch is a *sharded all-to-all*, not a gather: the compiled
    HLO of an EP train step contains all-to-all collectives."""
    cfg = _tiny_moe_cfg()
    shape = ShapeConfig("eq", 32, 8, "train")
    topo = strategy_lib.host_topology()
    plan = strategy_lib.parse("fsdp_ep4").to_plan(cfg, topo, shape)
    rt = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                          compute_dtype=jnp.float32, remat=False,
                          attn_min_chunked_len=64)
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, cfg.d_model))
    with par.use_mesh(plan.mesh):
        txt = jax.jit(lambda p, x: moe_lib.apply_moe(cfg, p, x, rt)[0]) \
            .lower(p, x).compile().as_text()
    assert "all-to-all" in txt


def test_ep_decode_pads_and_serves(eight_devices):
    """Decode batches too small to tile every mesh axis are zero-padded
    up to the shard count and still run the genuine EP all-to-all (the
    old silent fallback to GSPMD dropping served a different physical
    program than the planned one).  Numerics must match the dense oracle:
    pad rows lose every capacity race to real tokens."""
    from repro.core import expert as expert_lib
    cfg = _tiny_moe_cfg()
    shape = ShapeConfig("d", 64, 4, "decode")
    topo = strategy_lib.host_topology()
    plan = strategy_lib.parse("fsdp_ep2").to_plan(cfg, topo, shape)
    rt_s = par.make_runtime(cfg, plan, shape, param_dtype=jnp.float32,
                            compute_dtype=jnp.float32, remat=False)
    assert rt_s.moe_impl == "ep"      # derived from the plan, not hardcoded
    assert expert_lib.can_pad_tokens(cfg, rt_s)
    stats0 = expert_lib.dispatch_stats_snapshot()
    rt0 = Runtime(moe_impl="dense")

    key = jax.random.PRNGKey(1)
    params = tfm.init_params(cfg, key)
    B, S0 = 4, 9
    tokens = jax.random.randint(key, (B, S0 + 1), 0, cfg.vocab_size)
    _, cache0 = tfm.prefill(cfg, params, {"tokens": tokens[:, :S0]}, rt0,
                            max_len=shape.seq_len)
    logits0, _ = tfm.decode_step(cfg, params, cache0, tokens[:, S0:],
                                 jnp.asarray(S0, jnp.int32), rt0)
    with par.use_mesh(plan.mesh):
        pshard = par.param_shardings(cfg, plan, jax.eval_shape(lambda: params))
        params_s = jax.device_put(params, pshard)
        cshard = par.cache_shardings(cfg, plan, jax.eval_shape(lambda: cache0))
        cache_s = jax.device_put(cache0, cshard)
        logits_s, _ = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(cfg, p, c, t, pos, rt_s),
            out_shardings=(None, cshard))(
                params_s, cache_s, tokens[:, S0:], jnp.asarray(S0, jnp.int32))
    err = float(jnp.max(jnp.abs(logits0 - jax.device_get(logits_s))))
    assert err < TOL, err
    stats1 = expert_lib.dispatch_stats_snapshot()
    assert stats1["ep_padded_calls"] > stats0["ep_padded_calls"]
    assert stats1["ep_fallback_calls"] == stats0["ep_fallback_calls"]


def test_train_cli_ep_smoke(eight_devices):
    """The acceptance command: --strategy fsdp_ep4 trains deepseek-moe-16b
    tiny on the 8-virtual-device mesh."""
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)         # train.py forces 8 fake devices
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "deepseek-moe-16b", "--strategy", "fsdp_ep4",
         "--reduced", "--steps", "2", "--seq_len", "64", "--log_every", "1"],
        capture_output=True, text=True, timeout=1200, env=env)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "done: loss" in res.stdout, res.stdout[-3000:]


# ---------------------------------------------------------------------------
# cost model: strat.ep is consumed, the tp*pp proxy is gone
# ---------------------------------------------------------------------------

def test_costmodel_moe_a2a_uses_strat_ep():
    r_flat = cm.step_time(DEEPSEEK, cm.H100, cm.Strategy(64), 256, 4096)
    r_ep2 = cm.step_time(DEEPSEEK, cm.H100, cm.Strategy(64, ep=2), 256, 4096)
    r_ep8 = cm.step_time(DEEPSEEK, cm.H100, cm.Strategy(64, ep=8), 256, 4096)
    # no expert axis + no model axis -> the dispatch stays data-local
    assert r_flat.comm_breakdown["moe_a2a"] == 0.0
    assert r_ep8.comm_breakdown["moe_a2a"] > \
        r_ep2.comm_breakdown["moe_a2a"] > 0.0
    # the old proxy charged a2a by tp*pp: pp must NOT move the a2a term
    r_pp = cm.step_time(DEEPSEEK, cm.H100,
                        cm.Strategy(64, pp=4, microbatches=8), 256, 4096)
    assert r_pp.comm_breakdown["moe_a2a"] == 0.0
    # without an expert axis the GSPMD path reshards the expert buffer
    # over the whole model axis — cp sizes it just like tp
    r_cp = cm.step_time(DEEPSEEK, cm.H100, cm.Strategy(64, cp=4), 256, 4096)
    assert r_cp.comm_breakdown["moe_a2a"] > 0.0
    # ep shrinks the expert-param FSDP gather (1/ep slice, 1/ep group)
    assert r_ep8.comm_breakdown["fsdp_ag"] < r_flat.comm_breakdown["fsdp_ag"]


def test_costmodel_ep_divides_dp():
    assert not cm.Strategy(64, ep=3).valid()       # 3 does not divide 64
    assert cm.Strategy(64, ep=4).valid()
    assert cm.Strategy(64, ep=4).dp == 64          # ep lives inside dp


def test_dense_configs_charge_no_ep(eight_devices):
    """ep is an MoE-only degree: the planner never proposes it for dense
    models and the descriptor rejects it."""
    topo = strategy_lib.pod_topology(pods=1)
    shape = ShapeConfig("t", 4096, 256, "train")
    ranked = search(LLAMA2_7B, topo, shape, require_fits=False)
    assert all(p.strategy.ep == 1 for p in ranked)


# ---------------------------------------------------------------------------
# the paper's MoE crossover: EP overtakes pure FSDP when node bandwidth
# is starved (acceptance criterion; analogue of the PP Pareto test)
# ---------------------------------------------------------------------------

def _slow_fabric_topology():
    slow = dataclasses.replace(cm.H100, inter_bw=25e9, alpha_inter=25e-6)
    return Topology("slow-fabric", 256, island=8, hardware="H100",
                    hbm=80e9, hw_obj=slow)


def test_ep_on_pareto_front_when_node_bandwidth_constrained():
    """Once inter-island bandwidth is starved, all-gathering the expert
    stacks over the full FSDP group dominates the step; sharding experts
    over an 'expert' axis (paying the much smaller token all-to-all
    instead) must beat pure FSDP — and the planner must surface it."""
    topo = _slow_fabric_topology()
    shape = ShapeConfig("t", 4096, 256, "train")
    ranked = search(DEEPSEEK, topo, shape, dp_modes=("fsdp",),
                    tps=(1,), cps=(1,), pps=(1,), require_fits=False)
    assert any(p.strategy.ep > 1 for p in ranked)
    front = pareto_front(ranked, objectives=("wps", "tokens_per_joule"))
    assert any(p.strategy.ep > 1 for p in front), [p.spec for p in front]
    best_ep = max(p.score for p in ranked if p.strategy.ep > 1)
    best_flat = max(p.score for p in ranked
                    if p.strategy.ep == 1 and p.strategy.model_parallel == 1)
    assert best_ep > best_flat
    # and in the full default sweep, every pure-FSDP point is beaten by
    # some ep > 1 strategy (ep is ahead of pure FSDP, not just on par)
    full = search(DEEPSEEK, topo, shape, dp_modes=("fsdp",),
                  require_fits=False)
    best_ep_full = max(p.score for p in full if p.strategy.ep > 1)
    for p in full:
        if p.strategy.ep == 1 and p.strategy.model_parallel == 1:
            assert p.score < best_ep_full, p.spec


def test_issue_spec_examples_lower():
    """The spec strings named in the issue lower on the pod topology."""
    topo = strategy_lib.pod_topology(pods=1)
    shape = ShapeConfig("t", 4096, 256, "train")
    for spec, axes in (("fsdp_ep8", {"data": 32, "expert": 8, "model": 1}),
                       ("hsdp_tp2_ep4", {"data": 32, "expert": 4,
                                         "model": 2})):
        s = strategy_lib.parse(spec)
        plan = s.to_plan(DEEPSEEK, topo, shape, abstract=True)
        assert dict(plan.mesh.shape) == axes, (spec, dict(plan.mesh.shape))
        assert plan.expert == "expert"
        cost = s.to_cost_strategy(DEEPSEEK, topo)
        assert cost.ep == s.ep
