"""Pipeline schedule unit tests (promoted from the ad-hoc
tests/pipeline_check.py subprocess script): GPipe-scheduled layers over a
'pipe' mesh axis == sequential application, forward AND gradient, on the
shared 8-virtual-device fixture — including the composed (pipe, data) mesh
the Strategy lowering builds."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.core.parallel import use_mesh
from repro.core.pipeline import (batch_axes_spec, bubble_fraction,
                                 make_pipelined_block_fn, pipeline_apply)
from repro.models.layers import Runtime
from repro.models.transformer import (_apply_layer, _init_layer, _sig,
                                      _tree_stack)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=4, d_model=128)
    rt = Runtime()
    key = jax.random.PRNGKey(0)
    layers = [_init_layer(cfg, i, k) for i, k in
              enumerate(jax.random.split(key, 4))]
    # stacked layer params, leading dim = total layers (the pipe axis
    # shards it into contiguous stages)
    stacked = {"layers": _tree_stack(layers)}
    return cfg, rt, layers, stacked


def _sequential(cfg, rt, layers, x):
    M, mb, S, d = x.shape
    h = x.reshape(M * mb, S, d)
    for lp in layers:
        h, _, _ = _apply_layer(cfg, _sig(cfg, 0), lp, h, None, rt)
    return h.reshape(M, mb, S, d)


@pytest.mark.parametrize("mesh_axes", [("pipe",), ("pipe", "data")])
def test_pipeline_matches_sequential_fwd_and_grad(setup, eight_devices,
                                                  mesh_axes):
    cfg, rt, layers, stacked = setup
    if mesh_axes == ("pipe",):
        mesh = jax.make_mesh((4,), mesh_axes, devices=eight_devices[:4])
        batch_axes = ()
    else:
        mesh = jax.make_mesh((4, 2), mesh_axes, devices=eight_devices)
        batch_axes = ("data",)
    M, mb, S, d = 8, 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(0), (M, mb, S, d)) * 0.5
    stage_fn = make_pipelined_block_fn(cfg, rt)

    def pipelined(params, x):
        out, _aux = pipeline_apply(stage_fn, params, x, mesh, "pipe",
                                   batch_axes=batch_axes)
        return out

    with use_mesh(mesh):
        out_p = jax.jit(pipelined)(stacked, x)
    out_s = _sequential(cfg, rt, layers, x)
    assert float(jnp.max(jnp.abs(out_p - out_s))) < 1e-4

    # gradient path through shard_map + ppermute (reverse schedule)
    def loss_p(params):
        return jnp.sum(pipelined(params, x) ** 2)

    def loss_s(layers):
        return jnp.sum(_sequential(cfg, rt, layers, x) ** 2)

    with use_mesh(mesh):
        g_p = jax.jit(jax.grad(loss_p))(stacked)
    g_s = {"layers": _tree_stack(jax.grad(loss_s)(layers))}
    errs = [float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(g_p), jax.tree.leaves(g_s))]
    assert max(errs) < 5e-3, max(errs)


def test_pipeline_multi_layer_stages(setup, eight_devices):
    """4 layers over 2 stages: each stage scans its 2-layer local slice."""
    cfg, rt, layers, stacked = setup
    mesh = jax.make_mesh((2,), ("pipe",), devices=eight_devices[:2])
    M, mb, S, d = 4, 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, S, d)) * 0.5
    stage_fn = make_pipelined_block_fn(cfg, rt)
    with use_mesh(mesh):
        out_p = jax.jit(lambda p, x: pipeline_apply(
            stage_fn, p, x, mesh, "pipe")[0])(stacked, x)
    out_s = _sequential(cfg, rt, layers, x)
    assert float(jnp.max(jnp.abs(out_p - out_s))) < 1e-4


def test_bubble_fraction_formula():
    assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-9
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(2, 2) - 1 / 3) < 1e-9


def test_batch_axes_spec_fit_or_drop(eight_devices):
    mesh = jax.make_mesh((2, 4), ("pipe", "data"), devices=eight_devices)
    assert batch_axes_spec(mesh, ("data",), 8) == ("data",)
    assert batch_axes_spec(mesh, ("data",), 3) == ()   # not divisible
    assert batch_axes_spec(mesh, ("data",), 1) == ()   # cannot occupy
