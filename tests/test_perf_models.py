"""Property tests for the analytic FLOP / HBM-traffic / roofline models."""
import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import ShapeConfig
from repro.perf import bytes as bytes_lib
from repro.perf import flops as flops_lib

ARCHS = list_archs(assigned_only=True)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_flops_close_to_2nd(arch):
    """Forward FLOPs should be within ~3x of the 2·N_active·D floor
    (attention quadratic terms, routing and capacity slop on top)."""
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    fwd = flops_lib.forward_flops(cfg, shape)
    floor = flops_lib.model_flops(cfg, shape) / 3.0     # 2ND
    assert fwd > 0.5 * floor, (fwd, floor)
    assert fwd < 4.0 * floor, (fwd, floor)


@given(seq=st.sampled_from([512, 2048, 8192, 32768]),
       arch=st.sampled_from(ARCHS))
@settings(max_examples=40, deadline=None)
def test_flops_monotone_in_seq(seq, arch):
    cfg = get_config(arch)
    a = flops_lib.forward_flops(cfg, ShapeConfig("a", seq, 8, "train"))
    b = flops_lib.forward_flops(cfg, ShapeConfig("b", 2 * seq, 8, "train"))
    assert b > a


@given(arch=st.sampled_from(ARCHS))
@settings(max_examples=10, deadline=None)
def test_decode_flops_independent_of_cache_len_for_ssm(arch):
    cfg = get_config(arch)
    a = flops_lib.forward_flops(cfg, ShapeConfig("a", 32768, 8, "decode"))
    b = flops_lib.forward_flops(cfg, ShapeConfig("b", 524288, 8, "decode"))
    if cfg.mixer == "rwkv6" and cfg.attn_every <= 1:
        assert a == b                      # attention-free: O(1) per token
    else:
        assert b >= a


@given(n1=st.sampled_from([64, 256]), arch=st.sampled_from(ARCHS))
@settings(max_examples=20, deadline=None)
def test_hbm_bytes_decrease_with_devices_for_decode(n1, arch):
    cfg = get_config(arch)
    shape = SHAPES["decode_32k"]
    a = bytes_lib.hbm_bytes_per_device(cfg, shape, n1)
    b = bytes_lib.hbm_bytes_per_device(cfg, shape, 4 * n1)
    assert b <= a * 1.01


def test_train_traffic_includes_optimizer():
    cfg = get_config("qwen3-0.6b")
    t = bytes_lib.hbm_bytes_per_device(cfg, SHAPES["train_4k"], 256)
    p = bytes_lib.hbm_bytes_per_device(cfg, SHAPES["prefill_32k"], 256)
    # per-token-normalized train traffic exceeds inference traffic
    assert t / (256 * 4096) > p / (32 * 32768) * 0.5


def test_remat_adds_flops():
    cfg = get_config("granite-20b")
    shape = SHAPES["train_4k"]
    assert flops_lib.compiled_flops(cfg, shape, remat=True) > \
        flops_lib.compiled_flops(cfg, shape, remat=False)


def test_moe_flops_use_active_params():
    cfg = get_config("dbrx-132b")
    shape = SHAPES["train_4k"]
    m = flops_lib.model_flops(cfg, shape)
    dense_equiv = 6.0 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert m < 0.5 * dense_equiv          # 36B active of 132B


def test_swa_caps_decode_attention_flops():
    cfg = get_config("h2o-danube-1.8b")
    nosw = dataclasses.replace(cfg, sliding_window=0)
    f_sw = flops_lib.forward_flops(cfg, SHAPES["decode_32k"])
    f_full = flops_lib.forward_flops(nosw, SHAPES["decode_32k"])
    assert f_sw < f_full
