"""Mixer numerics: chunked parallel forms vs sequential oracles; MoE
dispatch invariants; blocked attention vs dense."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models.attention import _attend_blocked, _attend_dense, sdpa_causal
from repro.models.layers import Runtime
from repro.models import moe as moe_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models import mamba as mamba_lib

KEY = jax.random.PRNGKey(3)


# ---------------------------------------------------------------------------
# attention: blocked == dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,window", [(256, 0), (256, 64), (512, 100),
                                      (100, 0), (300, 64), (97, 0)])
def test_blocked_attention_matches_dense(S, window):
    ks = jax.random.split(KEY, 3)
    B, H, Kv, D = 2, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Kv, D))
    v = jax.random.normal(ks[2], (B, S, Kv, D))
    pos = jnp.arange(S)
    dense = _attend_dense(q, k, v, pos, pos, window, D ** -0.5)
    blocked = _attend_blocked(q, k, v, window, D ** -0.5, 64, 64)
    assert float(jnp.max(jnp.abs(dense - blocked))) < 1e-5


def test_blocked_attention_gradients_finite():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))

    def loss(q):
        return jnp.sum(_attend_blocked(q, k, v, 0, 32 ** -0.5, 64, 64) ** 2)

    g = jax.grad(loss)(q)
    assert bool(jnp.all(jnp.isfinite(g)))


# ---------------------------------------------------------------------------
# RWKV-6: chunked == recurrent
# ---------------------------------------------------------------------------

def test_wkv_chunked_matches_recurrent():
    ks = jax.random.split(KEY, 5)
    B, T, H, N = 2, 48, 2, 16
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, N)) - 2.0))
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    s0 = jax.random.normal(KEY, (B, H, N, N)) * 0.1
    y1, s1 = rwkv_lib.wkv_recurrent(r, k, v, w, u, s0)
    y2, s2 = rwkv_lib.wkv_chunked(r, k, v, w, u, s0, 16)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(s1 - s2))) < 1e-4


def test_wkv_step_matches_scan_tail():
    ks = jax.random.split(KEY, 5)
    B, T, H, N = 1, 9, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, N)) - 2.0))
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    s = jnp.zeros((B, H, N, N))
    ys = []
    for t in range(T):
        y, s = rwkv_lib.wkv_step(r[:, t], k[:, t], v[:, t], w[:, t], u, s)
        ys.append(y)
    y_ref, s_ref = rwkv_lib.wkv_recurrent(r, k, v, w, u, jnp.zeros_like(s))
    assert float(jnp.max(jnp.abs(jnp.stack(ys, 1) - y_ref))) < 1e-5
    assert float(jnp.max(jnp.abs(s - s_ref))) < 1e-5


# ---------------------------------------------------------------------------
# Mamba: chunked scan == step-by-step
# ---------------------------------------------------------------------------

def test_selective_scan_chunked_matches_steps():
    ks = jax.random.split(KEY, 5)
    B, T, di, ds = 2, 40, 8, 4
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, T, di)) - 1)
    Bt = jax.random.normal(ks[1], (B, T, ds))
    Ct = jax.random.normal(ks[2], (B, T, ds))
    x = jax.random.normal(ks[3], (B, T, di))
    A = -jnp.exp(jax.random.normal(ks[4], (di, ds)) * 0.3)
    h0 = jnp.zeros((B, di, ds))
    y1, h1 = mamba_lib.selective_scan(dt, Bt, Ct, x, A, h0, chunk=8)
    y2, h2 = mamba_lib._selective_scan_chunk(dt, Bt, Ct, x, A, h0)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-4


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_setup(cf=8.0, E=4, k=2):
    import dataclasses
    cfg = reduced(get_config("dbrx-132b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=E, top_k=k,
                                     capacity_factor=cf))
    p = moe_lib.init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.5
    return cfg, p, x


def test_moe_dropping_matches_dense_with_big_capacity():
    cfg, p, x = _moe_setup(cf=8.0)
    y_dense, aux1 = moe_lib.apply_moe(cfg, p, x, Runtime(moe_impl="dense"))
    y_drop, aux2 = moe_lib.apply_moe(cfg, p, x,
                                     Runtime(moe_impl="dropping", moe_groups=1))
    assert float(jnp.max(jnp.abs(y_dense - y_drop))) < 1e-4
    assert abs(float(aux1 - aux2)) < 1e-6


def test_moe_groups_do_not_change_result_with_big_capacity():
    cfg, p, x = _moe_setup(cf=8.0)
    y1, _ = moe_lib.apply_moe(cfg, p, x, Runtime(moe_impl="dropping", moe_groups=1))
    y4, _ = moe_lib.apply_moe(cfg, p, x, Runtime(moe_impl="dropping", moe_groups=4))
    assert float(jnp.max(jnp.abs(y1 - y4))) < 1e-4


def test_moe_dropping_drops_under_tight_capacity():
    cfg, p, x = _moe_setup(cf=0.25)
    y_drop, _ = moe_lib.apply_moe(cfg, p, x, Runtime(moe_impl="dropping"))
    y_dense, _ = moe_lib.apply_moe(cfg, p, x, Runtime(moe_impl="dense"))
    # some tokens dropped -> outputs differ; dropped rows fall back toward 0
    assert float(jnp.max(jnp.abs(y_drop - y_dense))) > 1e-3
    assert bool(jnp.all(jnp.isfinite(y_drop)))


def test_moe_dropping_gradients_match_dense():
    """The custom-VJP routed-take dispatch must backprop exactly like the
    dense oracle when nothing is dropped (capacity ample)."""
    cfg, p, x = _moe_setup(cf=8.0)

    def loss(impl):
        def f(params, xx):
            y, aux = moe_lib.apply_moe(cfg, params, xx,
                                       Runtime(moe_impl=impl, moe_groups=2))
            return jnp.sum(y ** 2) + aux
        return f

    gd_p, gd_x = jax.grad(loss("dense"), argnums=(0, 1))(p, x)
    gr_p, gr_x = jax.grad(loss("dropping"), argnums=(0, 1))(p, x)
    assert float(jnp.max(jnp.abs(gd_x - gr_x))) < 1e-3
    for a, b in zip(jax.tree.leaves(gd_p), jax.tree.leaves(gr_p)):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-3


def test_routed_take_vjp_is_exact():
    """Directional-derivative check of _routed_take against autodiff of an
    equivalent (scatter-based) formulation."""
    key = jax.random.PRNGKey(0)
    n, m, d = 12, 8, 5
    x = jax.random.normal(key, (n, d))
    # injective partial map: slots 0..m-1 take distinct rows or -1
    idx = jnp.asarray([3, -1, 7, 0, -1, 11, 5, 2], jnp.int32)
    inv = jnp.full((n,), -1, jnp.int32)
    for slot, item in enumerate([3, -1, 7, 0, -1, 11, 5, 2]):
        if item >= 0:
            inv = inv.at[item].set(slot)

    def f_routed(x):
        return jnp.sum(jnp.sin(moe_lib._routed_take(x, idx, inv)) ** 2)

    def f_ref(x):
        mask = (idx >= 0)[:, None].astype(x.dtype)
        y = x[jnp.maximum(idx, 0)] * mask
        return jnp.sum(jnp.sin(y) ** 2)

    g1 = jax.grad(f_routed)(x)
    g2 = jax.grad(f_ref)(x)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-5


def test_moe_router_weights_normalized():
    cfg, p, x = _moe_setup()
    xf = x.reshape(-1, cfg.d_model)
    probs, weights, ids, aux = moe_lib._router(cfg, p, xf)
    assert jnp.allclose(weights.sum(-1), 1.0, atol=1e-5)
    assert float(aux) >= 0
