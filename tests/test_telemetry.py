"""Telemetry subsystem: spans/metrics/sinks, Chrome-trace export, drift
monitor math, serve latency accounting, supervisor event-log migration,
and the train-CLI trace smoke (acceptance: per-step spans sum to within
10% of wall-clock step time)."""
import json
import math
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro import telemetry as tel


class FakeClock:
    def __init__(self, t=10.0):
        # starts nonzero: lifecycle code treats t == 0.0 as "not reached"
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


def make_recorder(clk=None):
    clk = clk or FakeClock()
    mem = tel.InMemorySink()
    rec = tel.Recorder(sinks=[mem], clock=clk, annotate_jax=False)
    return rec, mem, clk


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting_depth_parent_and_timing():
    rec, mem, clk = make_recorder()
    with rec.span("outer"):
        clk.advance(1.0)
        with rec.span("inner"):
            clk.advance(0.25)
        clk.advance(0.5)
    spans = mem.by_kind("span")
    # children close (and emit) before parents
    assert [s["name"] for s in spans] == ["inner", "outer"]
    inner, outer = spans
    assert inner["depth"] == 1 and inner["parent"] == "outer"
    assert outer["depth"] == 0 and "parent" not in outer
    assert inner["dur"] == pytest.approx(0.25)
    assert outer["dur"] == pytest.approx(1.75)
    assert outer["ts"] == pytest.approx(10.0)
    assert inner["ts"] == pytest.approx(11.0)


def test_span_emitted_on_exception_and_stack_unwinds():
    rec, mem, clk = make_recorder()
    with pytest.raises(ValueError):
        with rec.span("boom"):
            clk.advance(0.5)
            raise ValueError("x")
    (span,) = mem.by_kind("span")
    assert span["name"] == "boom" and span["dur"] == pytest.approx(0.5)
    # the thread-local stack unwound: a new span is top-level again
    with rec.span("after"):
        pass
    assert mem.by_name("after")[0]["depth"] == 0


def test_span_attrs_mutable_during_block():
    rec, mem, _ = make_recorder()
    with rec.span("s", static=1) as attrs:
        attrs["tokens"] = 128
    (span,) = mem.by_kind("span")
    assert span["attrs"] == {"static": 1, "tokens": 128}


def test_span_thread_local_nesting():
    rec, mem, _ = make_recorder()
    done = threading.Event()

    def worker():
        with rec.span("t2"):
            done.wait(5)

    t = threading.Thread(target=worker)
    with rec.span("t1-outer"):
        t.start()
        # the other thread's open span must not become our parent
        with rec.span("t1-inner"):
            pass
        done.set()
    t.join()
    inner = mem.by_name("t1-inner")[0]
    assert inner["parent"] == "t1-outer" and inner["depth"] == 1
    assert mem.by_name("t2")[0]["depth"] == 0


def test_null_recorder_is_inert():
    with tel.NULL.span("x") as attrs:
        assert attrs == {}
    tel.NULL.counter("c")
    tel.NULL.gauge("g", 1.0)
    tel.NULL.observe("h", 1.0)
    assert tel.NULL.metrics.snapshot() == {}
    with pytest.raises(RuntimeError):
        tel.NULL.add_sink(tel.InMemorySink())


# ---------------------------------------------------------------------------
# metrics: exactness vs sorted-list oracle
# ---------------------------------------------------------------------------

def _oracle_percentile(values, q):
    s = sorted(values)
    if q <= 0:
        return s[0]
    return s[max(math.ceil(q / 100.0 * len(s)), 1) - 1]


@pytest.mark.parametrize("n", [1, 2, 5, 100, 997])
@pytest.mark.parametrize("q", [0, 1, 50, 90, 99, 100])
def test_histogram_percentiles_exact_vs_oracle(n, q):
    rng = np.random.default_rng(n * 1000 + q)
    values = rng.lognormal(mean=-3, sigma=2, size=n).tolist()
    h = tel.Histogram("h")
    for v in values:
        h.observe(v)
    assert h.percentile(q) == _oracle_percentile(values, q)
    # nearest-rank percentiles are actual observations, never interpolants
    assert h.percentile(q) in values


def test_histogram_bucket_counts_and_snapshot():
    h = tel.Histogram("h", buckets=[0.1, 1.0, 10.0])
    for v in [0.05, 0.5, 0.5, 5.0, 50.0]:
        h.observe(v)
    assert h.bucket_counts == [1, 2, 1, 1]     # <=0.1, <=1, <=10, +inf
    snap = h.snapshot()
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(56.05)
    assert snap["p50"] == 0.5
    assert snap["buckets"] == {"0.1": 1, "1.0": 2, "10.0": 1, "inf": 1}


def test_histogram_weighted_observe():
    h = tel.Histogram("h")
    h.observe(2.0, n=3)
    assert h.count == 3 and h.sum == pytest.approx(6.0)
    assert h.percentile(99) == 2.0


def test_registry_snapshot_and_type_guard():
    reg = tel.MetricsRegistry()
    reg.counter("c").inc(2)
    reg.gauge("g").set(1.5)
    snap = reg.snapshot()
    assert snap["c"] == {"type": "counter", "value": 2}
    assert snap["g"] == {"type": "gauge", "value": 1.5}
    with pytest.raises(TypeError):
        reg.gauge("c")


def test_percentile_empty_raises():
    with pytest.raises(ValueError):
        tel.percentile([], 50)


# ---------------------------------------------------------------------------
# event schema + sinks
# ---------------------------------------------------------------------------

def test_event_schema_validation():
    ok = tel.make_event("gauge", "g", 1.0, value=2.0)
    assert tel.validate_event(ok) == []
    assert tel.validate_event({"kind": "gauge"})          # missing fields
    assert tel.validate_event({"ts": 0, "kind": "span", "name": "s",
                               "dur": -1})                # negative dur
    assert tel.validate_event({"ts": 0, "kind": "nope", "name": "s"})
    assert tel.validate_event([1, 2])
    with pytest.raises(ValueError):
        tel.make_event("span", "s", 0.0)                  # span needs dur


def test_jsonl_sink_roundtrip_validates(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    rec = tel.Recorder(sinks=[tel.JsonlSink(path)], clock=FakeClock(),
                       annotate_jax=False)
    with rec.span("s"):
        rec.counter("c")
        rec.gauge("g", 1.0)
        rec.observe("h", 0.5)
    rec.event("e", why="test")
    rec.close()
    n, errs = tel.validate_jsonl(path)
    assert n == 5 and errs == []
    kinds = [json.loads(l)["kind"] for l in open(path)]
    assert sorted(kinds) == ["counter", "event", "gauge", "histogram",
                             "span"]


def test_schema_check_cli(tmp_path):
    good = tmp_path / "good.jsonl"
    good.write_text(json.dumps(tel.make_event("gauge", "g", 1.0,
                                              value=2.0)) + "\n")
    from repro.telemetry.__main__ import main as check_main
    assert check_main([str(good)]) == 0
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"kind": "gauge"}\n')
    assert check_main([str(bad)]) == 1
    assert check_main([str(tmp_path)]) == 1      # dir scan finds bad too
    assert check_main([str(tmp_path / "missing.jsonl")]) == 1


# ---------------------------------------------------------------------------
# Chrome trace / Perfetto export
# ---------------------------------------------------------------------------

def test_chrome_trace_schema_and_units(tmp_path):
    path = str(tmp_path / "trace.json")
    clk = FakeClock()
    rec = tel.Recorder(sinks=[tel.ChromeTraceSink(path)], clock=clk,
                       annotate_jax=False)
    with rec.span("step", step_num=3):
        clk.advance(0.002)
    rec.gauge("wps", 1000.0)
    rec.close()
    n, errs = tel.validate_chrome_trace(path)
    assert errs == [] and n >= 4       # process+thread meta, span, counter
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    span = next(e for e in evs if e["ph"] == "X")
    assert span["name"] == "step"
    assert span["dur"] == pytest.approx(2000.0)    # seconds -> µs
    assert span["args"]["step_num"] == 3
    counter = next(e for e in evs if e["ph"] == "C")
    assert counter["name"] == "wps"
    assert counter["args"]["value"] == 1000.0
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)


def test_chrome_trace_invalid_files(tmp_path):
    bad = tmp_path / "trace.json"
    bad.write_text("{}")
    _, errs = tel.validate_chrome_trace(str(bad))
    assert errs
    bad.write_text(json.dumps(
        {"traceEvents": [{"ph": "X", "name": "s", "ts": 0}]}))
    _, errs = tel.validate_chrome_trace(str(bad))
    assert any("dur" in e for e in errs)


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------

def test_drift_monitor_ratios_on_synthetic_pairs():
    rec, mem, _ = make_recorder()
    mon = tel.DriftMonitor(
        {"step": 1.0, "compute": 0.6, "collective": 0.3, "bubble": 0.1},
        telemetry=rec)
    w = mon.observe({"step": 2.0, "compute": 0.6, "collective": 0.15,
                     "data": 0.01}, n_steps=10)
    r = w["predicted_over_measured"]
    assert r["step"] == pytest.approx(0.5)
    assert r["compute"] == pytest.approx(1.0)
    assert r["collective"] == pytest.approx(2.0)
    assert "data" not in r          # measured-only term: no prediction
    assert "bubble" not in r        # predicted-only term: no measurement
    gauges = {e["name"]: e["value"] for e in mem.by_kind("gauge")}
    assert gauges["drift/predicted_over_measured/step"] == \
        pytest.approx(0.5)
    assert gauges["drift/predicted_over_measured/collective"] == \
        pytest.approx(2.0)


def test_drift_monitor_zero_measured_gives_null_not_inf():
    mon = tel.DriftMonitor({"collective": 0.3})
    w = mon.observe({"collective": 0.0})
    assert w["predicted_over_measured"]["collective"] is None
    assert mon.summary()["mean_predicted_over_measured"] == {}


def test_drift_monitor_window_accumulation_and_artifact(tmp_path):
    mon = tel.DriftMonitor({"step": 1.0}, meta={"spec": "fsdp"})
    mon.observe({"step": 2.0}, n_steps=5)
    mon.observe({"step": 1.0}, n_steps=5)
    path = str(tmp_path / "drift.json")
    doc = mon.write(path)
    assert doc["n_windows"] == 2
    assert doc["mean_predicted_over_measured"]["step"] == \
        pytest.approx(0.75)
    on_disk = json.load(open(path))
    assert on_disk == doc
    assert on_disk["meta"]["spec"] == "fsdp"
    assert [w["window"] for w in on_disk["windows"]] == [0, 1]


def test_costmodel_decomposition_consistency():
    from repro.configs.llama2 import LLAMA2_7B
    from repro.core import costmodel as cm
    rep = cm.step_time(LLAMA2_7B, cm.H100, cm.Strategy(128, zero_stage=2),
                       256, 4096)
    d = rep.decomposition()
    assert d["step"] == rep.t_step
    assert d["compute"] == rep.t_compute
    assert d["collective"] == rep.t_comm_exposed
    assert d["bubble"] >= 0
    assert d["compute"] + d["collective"] + d["bubble"] == \
        pytest.approx(d["step"])
    # every nonzero comm kind appears namespaced
    for k, v in rep.comm_breakdown.items():
        assert (f"comm/{k}" in d) == bool(v)


# ---------------------------------------------------------------------------
# serve: per-request latency accounting vs injectable clock
# ---------------------------------------------------------------------------

def test_scheduler_lifecycle_latencies_fake_clock():
    from repro.serve.paged_cache import BlockAllocator
    from repro.serve.scheduler import Scheduler
    rec, mem, clk = make_recorder(FakeClock(10.0))
    sched = Scheduler(n_slots=1, allocator=BlockAllocator(64, 16),
                      clock=clk, telemetry=rec)
    r0 = sched.submit(np.arange(8), n_new=4)
    clk.advance(1.0)
    r1 = sched.submit(np.arange(8), n_new=4)
    clk.advance(2.0)
    sched.admit()                       # only r0 fits (1 slot)
    first = sched.running[0]            # request in slot 0
    assert first.rid == r0
    assert first.t_submit == 10.0 and first.t_admit == 13.0
    clk.advance(4.0)
    sched.complete(first)
    assert first.t_finish == 17.0
    sched.admit()                       # r1 admitted after r0 freed
    second = sched.running[0]
    assert second.rid == r1 and second.t_admit == 17.0

    snap = rec.metrics.snapshot()
    assert snap["serve/queue_wait_s"]["count"] == 2
    assert sorted(e["value"] for e in
                  mem.by_name("serve/queue_wait_s")) == [3.0, 6.0]
    assert snap["serve/total_latency_s"]["p50"] == 7.0
    assert snap["serve/submitted"]["value"] == 2
    assert snap["serve/admitted"]["value"] == 2
    assert snap["serve/completed"]["value"] == 1


def test_scheduler_expiry_and_cancel_counters():
    from repro.serve.paged_cache import BlockAllocator
    from repro.serve.scheduler import Scheduler
    rec, _, clk = make_recorder(FakeClock(10.0))
    sched = Scheduler(n_slots=2, allocator=BlockAllocator(64, 16),
                      clock=clk, telemetry=rec)
    sched.submit(np.arange(4), n_new=2, ttl_s=1.0)
    rid2 = sched.submit(np.arange(4), n_new=2)
    sched.admit()
    clk.advance(2.0)
    assert len(sched.expire()) == 1
    sched.cancel(rid2)
    snap = rec.metrics.snapshot()
    assert snap["serve/expired"]["value"] == 1
    assert snap["serve/cancelled"]["value"] == 1
    assert "serve/completed" not in snap


def test_engine_telemetry_accounting():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config, reduced
    from repro.models import Runtime, init_params
    from repro.serve.engine import ServeEngine

    cfg = reduced(get_config("qwen3-0.6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    # recorder stamps use its own (fake) clock; the engine keeps the
    # real monotonic clock for lifecycle timestamps
    rec, mem, _ = make_recorder()
    eng = ServeEngine(cfg, params, Runtime(), max_len=64, n_slots=2,
                      telemetry=rec)
    assert eng.paged_ok
    prompts = np.ones((2, 8), np.int32)
    out = eng.generate(prompts, n_new=6, key=jax.random.PRNGKey(1))
    assert out.shape == (2, 14)
    snap = rec.metrics.snapshot()
    assert snap["serve/submitted"]["value"] == 2
    assert snap["serve/completed"]["value"] == 2
    # 2 requests x 6 tokens, each with a latency sample: the 2 first
    # tokens come out of prefill (TTFT), the remaining 10 from decode
    # segments (weighted per-token observations)
    ttft = snap["serve/ttft_s"]
    tok = snap["serve/token_latency_s"]
    assert ttft["count"] == 2
    assert ttft["count"] + tok["count"] == 12
    assert snap["serve/batch_occupancy"]["value"] is not None
    assert 0.0 <= snap["serve/block_util"]["value"] <= 1.0
    assert mem.by_name("serve/tick")
    assert mem.by_name("serve/prefill_chunk")
    assert mem.by_name("serve/decode_segment")


# ---------------------------------------------------------------------------
# trainer + supervisor integration
# ---------------------------------------------------------------------------

def _tiny_train(telemetry, drift=None, steps=4, fault_plan=None):
    import jax
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeConfig
    from repro import strategy as strategy_lib
    from repro.core import parallel as par
    from repro.data.pipeline import Batcher, SyntheticSource
    from repro.train.trainer import TrainConfig, train_loop

    cfg = reduced(get_config("qwen3-0.6b"), n_layers=2, d_model=64)
    shape = ShapeConfig("tel", 16, 4, "train")
    strat = strategy_lib.parse("ddp")
    topo = strategy_lib.host_topology()
    plan = strat.to_plan(cfg, topo, shape)
    rt = par.make_runtime(cfg, plan, shape)
    tc = TrainConfig(steps=steps, warmup=1, log_every=2)
    return train_loop(cfg, plan, rt, tc,
                      Batcher(SyntheticSource(cfg.vocab_size, seed=7),
                              16, 4),
                      key=jax.random.PRNGKey(0), fault_plan=fault_plan,
                      telemetry=telemetry, drift=drift)


def test_trainer_spans_gauges_and_drift_windows():
    pytest.importorskip("jax")
    rec, mem, _ = make_recorder(time.monotonic)
    drift = tel.DriftMonitor({"step": 1e-3, "compute": 5e-4},
                             telemetry=rec)
    _tiny_train(rec, drift=drift, steps=4)
    steps = mem.by_name("train/step")
    assert len(steps) == 4
    assert [s["attrs"]["step_num"] for s in steps] == [0, 1, 2, 3]
    assert len(mem.by_name("train/dispatch")) == 4
    # dispatch and wait are separate spans, and the host sync happens
    # only on logging windows (steps 0 [first], 1, 3 with log_every=2)
    # — the async-dispatch satellite
    assert len(mem.by_name("train/wait")) == 3
    snap = rec.metrics.snapshot()
    assert snap["train/wps"]["value"] > 0
    assert 0.0 <= snap["train/goodput_frac"]["value"] <= 1.0
    # one measured drift window per logging window, with a real ratio
    assert len(drift.windows) == 3
    for w in drift.windows:
        assert w["measured"]["step"] > 0
        assert w["predicted_over_measured"]["step"] is not None


def test_trainer_per_step_sync_gated_on_stragglers():
    pytest.importorskip("jax")
    from repro.resilience.faults import FaultEvent, FaultPlan
    # a fault plan without stragglers keeps dispatch async (log-window
    # syncs only); a straggler plan needs the measured step time, so it
    # syncs every step
    no_straggler = FaultPlan(
        events=[FaultEvent(step=10 ** 6, kind="ckpt_io")])
    straggler = FaultPlan(
        events=[FaultEvent(step=10 ** 6, kind="straggler",
                           magnitude=1.5)])
    for plan, n_waits_expected in ((no_straggler, 3), (straggler, 4)):
        rec, mem, _ = make_recorder(time.monotonic)
        _tiny_train(rec, steps=4, fault_plan=plan)
        assert len(mem.by_name("train/wait")) == n_waits_expected


def test_supervisor_event_log_jsonl_sibling(tmp_path):
    from repro.resilience.supervisor import Supervisor, SupervisorConfig
    log = str(tmp_path / "events.json")
    rec, mem, _ = make_recorder()
    sup = Supervisor(SupervisorConfig(max_restarts=1, backoff_base_s=0.0,
                                      event_log_path=log), telemetry=rec)
    calls = {"n": 0}

    def attempt(n, strategy, topology):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("boom")
        return "ok"

    assert sup.run(attempt) == "ok"
    # pinned summary format intact
    doc = json.load(open(log))
    assert doc["n_failures"] == 1
    assert [e["kind"] for e in doc["events"]] == ["failure", "completed"]
    assert "backoff_s" in doc["events"][0]       # post-record mutation
    # telemetry-schema sibling, written by the shared sink, validates
    sib = str(tmp_path / "events.jsonl")
    n, errs = tel.validate_jsonl(sib)
    assert errs == [] and n == 2
    lines = [json.loads(l) for l in open(sib)]
    assert lines[0]["name"] == "supervisor/failure"
    assert lines[0]["attrs"]["backoff_s"] == 0.0
    assert lines[1]["name"] == "supervisor/completed"
    # recorder counters observed the lifecycle
    snap = rec.metrics.snapshot()
    assert snap["supervisor/failure"]["value"] == 1
    assert snap["supervisor/completed"]["value"] == 1
    assert mem.by_name("supervisor/attempt")


# ---------------------------------------------------------------------------
# train-CLI smoke: well-formed trace artifact (acceptance criterion)
# ---------------------------------------------------------------------------

def test_train_cli_trace_smoke(tmp_path):
    pytest.importorskip("jax")
    trace = str(tmp_path / "trace.json")
    jsonl = str(tmp_path / "events.jsonl")
    drift = str(tmp_path / "drift.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--reduced",
         "--steps", "6", "--log_every", "2", "--seq_len", "32",
         "--global_batch", "4", "--host_devices", "2",
         "--strategy", "fsdp", "--trace", trace,
         "--metrics_jsonl", jsonl, "--drift_report", drift],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]

    # every emitted JSONL event validates against the schema
    n, errs = tel.validate_jsonl(jsonl)
    assert errs == [] and n > 0

    # the trace is loadable Chrome-trace JSON...
    n, errs = tel.validate_chrome_trace(trace)
    assert errs == [] and n > 0
    evs = json.load(open(trace))["traceEvents"]
    steps = [e for e in evs if e["ph"] == "X" and e["name"] == "train/step"]
    assert len(steps) == 6
    # ...whose per-step spans sum to within 10% of the wall-clock the
    # loop spent (first span start -> last span end), and never overlap
    total_span = sum(e["dur"] for e in steps)
    wall = max(e["ts"] + e["dur"] for e in steps) - \
        min(e["ts"] for e in steps)
    assert total_span >= 0.9 * wall
    assert total_span <= 1.01 * wall

    # drift artifact has per-term ratios including the step term
    doc = json.load(open(drift))
    assert doc["n_windows"] >= 1
    assert doc["predicted"]["compute"] > 0
    assert doc["predicted"]["collective"] >= 0
    ratios = doc["windows"][0]["predicted_over_measured"]
    assert ratios.get("step") is not None
