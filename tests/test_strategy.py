"""Unified strategy API tests (ISSUE 1): spec round-trips, cost-model /
SPMD-lowering group-size agreement, and planner search contracts.

Group-size agreement uses AbstractMesh lowering (no devices needed), so
the 512-chip pod topology is exercised on any host; search-lowers tests
run on the real host mesh (however many devices pytest sees).

Property tests (hypothesis, skipped when it is not installed): every
spec string round-trips parse -> format -> parse, and for every valid
strategy the collective group sizes ``to_cost_strategy`` reports equal
the mesh axis sizes ``to_plan`` builds — including the 'pipe' axis.
"""
import dataclasses

import jax
import pytest
from hypothesis import assume, given, settings
from hypothesis import strategies as st

from repro import strategy as strategy_lib
from repro.configs import SHAPES, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.configs.llama2 import LLAMA2_7B
from repro.core import costmodel as cm
from repro.core import parallel as par
from repro.strategy import (Strategy, StrategyError, Topology, parse,
                            pareto_front, search)

TRAIN = ShapeConfig("t", 4096, 256, "train")
POD2 = strategy_lib.pod_topology(pods=2)
POD1 = strategy_lib.pod_topology(pods=1)


# ---------------------------------------------------------------------------
# spec strings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [
    Strategy(),
    Strategy(dp_mode="fsdp", tp=4),
    Strategy(dp_mode="hsdp", cp=8),
    Strategy(dp_mode="ddp"),
    Strategy(dp_mode="fsdp", tp=2, zero_stage=2, grad_accum=4),
    Strategy(dp_mode="hsdp", tp=4, microbatches=8, seq_parallel=False),
    Strategy(dp_mode="fsdp", pp=4, microbatches=16),
    Strategy(dp_mode="fsdp", tp=2, attn="context"),
    Strategy(dp_mode="hsdp", tp=8, attn="head_tp", zero_stage=3),
    Strategy(dp_mode="fsdp", ep=8),
    Strategy(dp_mode="hsdp", tp=2, ep=4),
    Strategy(dp_mode="fsdp", pp=4, microbatches=8, sched="1f1b"),
    Strategy(dp_mode="fsdp", tp=2, pp=2, ep=2, microbatches=4,
             sched="1f1b"),
    Strategy(dp_mode="hsdp", pp=2, microbatches=4, grad_accum=2,
             sched="1f1b", seq_parallel=False),
    Strategy(dp_mode="fsdp", pp=4, microbatches=8, sched="1f1b_i2"),
    Strategy(dp_mode="fsdp", pp=2, microbatches=8, sched="1f1b_i4",
             overlap=True),
    Strategy(dp_mode="fsdp", pp=2, microbatches=4, sched="zb"),
    Strategy(dp_mode="hsdp", tp=2, overlap=True),
])
def test_spec_round_trip(s):
    assert parse(s.format()) == s


def test_spec_defaults_and_aliases():
    assert parse("hsdp_tp4_cp1") == parse("hsdp_tp4")
    assert parse("hsdp") == Strategy()
    assert parse("fsdp_cp8").cp == 8
    assert parse("ddp").zero == 0
    assert parse("hsdp_tp4").zero == 3
    assert parse("fsdp_tp2_ctx").attn == "context"
    assert not parse("hsdp_nosp").seq_parallel


@pytest.mark.parametrize("bad", ["", "zorp_tp2", "hsdp_tp", "hsdp_xp4",
                                 "hsdp_tp4_tp8", "tp4", "fsdp_1f1b",
                                 "fsdp_pp2_mb4_1f1b_gpipe",
                                 "fsdp_zb",                  # sched w/o pp
                                 "fsdp_pp2_mb4_1f1b_i1",     # v must be >= 2
                                 "fsdp_pp2_mb4_i2",          # i<v> needs 1f1b
                                 "fsdp_pp4_mb6_1f1b_i2",     # mb % pp != 0
                                 "ddp_ovl",                  # ovl needs zero>=2
                                 "fsdp_z0_ovl"])
def test_spec_parse_rejects(bad):
    with pytest.raises(StrategyError):
        parse(bad)


def test_descriptor_validation():
    with pytest.raises(StrategyError):
        Strategy(tp=0)
    with pytest.raises(StrategyError):
        Strategy(dp_mode="zorp")
    with pytest.raises(StrategyError):
        Strategy(sched="interleaved")
    with pytest.raises(StrategyError):
        Strategy(sched="1f1b")        # sched token without a pipeline
    # tp and cp share the model axis
    with pytest.raises(StrategyError):
        Strategy(tp=2, cp=2).check(POD1)
    # a pipeline that cannot fill (mb < pp) is a construction error
    with pytest.raises(StrategyError):
        Strategy(pp=2)
    # pp > 1 lowers now (ISSUE 3): well-specified pipelines pass check
    Strategy(pp=2, microbatches=4).check(POD1)
    Strategy(pp=2, microbatches=4).check(POD1, LLAMA2_7B)
    assert not Strategy(tp=5).lowerable(POD1)       # 5 does not divide 256
    assert Strategy(tp=4).lowerable(POD1)
    # ISSUE 10 schedule-frontier degrees
    with pytest.raises(StrategyError):
        Strategy(sched="zb")                        # sched without a pipeline
    with pytest.raises(StrategyError):
        Strategy(pp=2, microbatches=4, sched="1f1b_i1")    # v >= 2
    with pytest.raises(StrategyError):
        Strategy(pp=4, microbatches=6, sched="1f1b_i2")    # mb % pp != 0
    with pytest.raises(StrategyError):
        Strategy(dp_mode="ddp", overlap=True)       # no sharded params
    # interleaving re-chunks the stack into pp*v slices: a 28-layer stack
    # splits over pp=4 stages (28 % 4 == 0) but not into 8 v-chunks
    Strategy(pp=2, microbatches=4, sched="1f1b_i2").check(POD1, LLAMA2_7B)
    Strategy(pp=2, microbatches=4, sched="zb").check(POD1, LLAMA2_7B)
    odd28 = dataclasses.replace(LLAMA2_7B, n_layers=28)
    Strategy(pp=4, microbatches=8, sched="1f1b").check(POD1, odd28)
    with pytest.raises(StrategyError):
        Strategy(pp=4, microbatches=8, sched="1f1b_i2").check(POD1, odd28)


def test_mb_lt_pp_is_error_not_silent_clamp():
    """Regression (descriptor.py): under-specified mb < pp used to be
    silently clamped to pp inside to_cost_strategy, so the cost model
    priced a pipeline the lowering would not run.  Now it is a
    StrategyError at validation time, and the analytic microbatch count
    is exactly the descriptor's."""
    with pytest.raises(StrategyError):
        parse("fsdp_pp4_mb2")
    with pytest.raises(StrategyError):
        Strategy(pp=4, microbatches=2)
    cost = Strategy(dp_mode="fsdp", pp=4, microbatches=16).to_cost_strategy(
        LLAMA2_7B, POD1)
    assert cost.microbatches == 16 and cost.pp == 4


def test_pp_model_constraints():
    """pp stages need a uniform layer stack; hybrids are rejected with
    cfg-aware validation (and still lower fine without pp).  MoE no
    longer blocks pp — the aux loss threads through the stage fn — but
    deepseek-moe's dense layer 0 breaks stack uniformity."""
    s = Strategy(dp_mode="fsdp", pp=2, microbatches=8)
    s.check(POD1, LLAMA2_7B)                      # uniform: ok
    jamba = get_config("jamba-v0.1-52b")
    with pytest.raises(StrategyError):
        s.check(POD1, jamba)                      # hybrid layer_plan
    assert Strategy(dp_mode="fsdp").lowerable(POD1, jamba)
    moe = get_config("deepseek-moe-16b")
    with pytest.raises(StrategyError):
        s.check(POD1, moe)                        # non-uniform (layer 0)
    uniform_moe = dataclasses.replace(
        moe, moe=dataclasses.replace(moe.moe, moe_start_layer=0))
    s.check(POD1, uniform_moe)                    # all-MoE stack: pp ok
    # layer count must split into contiguous stages
    odd = dataclasses.replace(LLAMA2_7B, n_layers=31)
    with pytest.raises(StrategyError):
        s.check(POD1, odd)


def test_ep_model_constraints():
    """ep needs an MoE config whose expert count it divides; ep stays
    inside the data axis.  ep x pp now composes (ISSUE 5): the expert
    all-to-all runs inside the pipeline stage body."""
    moe = get_config("deepseek-moe-16b")          # 64 routed experts
    Strategy(dp_mode="fsdp", ep=8).check(POD1, moe)
    with pytest.raises(StrategyError):
        Strategy(dp_mode="fsdp", ep=8).check(POD1, LLAMA2_7B)   # dense
    odd_e = dataclasses.replace(
        moe, moe=dataclasses.replace(moe.moe, n_experts=48))
    with pytest.raises(StrategyError):
        Strategy(dp_mode="fsdp", ep=32).check(POD1, odd_e)      # 48 % 32
    # ep x pp is a constructible, lowerable composition now — the old
    # StrategyError is gone (the uniform-stack rule still applies)
    uniform_moe = dataclasses.replace(
        moe, moe=dataclasses.replace(moe.moe, moe_start_layer=0))
    s = Strategy(dp_mode="fsdp", pp=2, ep=2, microbatches=8)
    s.check(POD1, uniform_moe)
    assert s.lowerable(POD1, uniform_moe)
    # hsdp: ep must divide the island-local data group
    assert Strategy(dp_mode="hsdp", ep=8).lowerable(POD2, moe)
    cost = Strategy(dp_mode="fsdp", ep=8).to_cost_strategy(moe, POD1)
    assert cost.ep == 8 and cost.dp % cost.ep == 0


# ---------------------------------------------------------------------------
# cost model <-> SPMD lowering agreement (the acceptance criterion)
# ---------------------------------------------------------------------------

def _agreement(cfg, topo, shape=TRAIN, **search_kw):
    ranked = search(cfg, topo, shape, require_fits=False, **search_kw)
    assert ranked, "planner returned no strategies"
    for p in ranked:
        s = p.strategy
        plan = s.to_plan(cfg, topo, shape, abstract=True)
        cost = s.to_cost_strategy(cfg, topo)
        # data-parallel group: batch axes of the mesh vs analytic dp
        # (the expert axis is part of the batch axes)
        assert plan.axis_size(plan.dp) == cost.dp, s.format()
        # model-parallel group: the mesh model axis vs tp*cp charged
        assert plan.tp_size == cost.tp * cost.cp, s.format()
        # pipeline stages: the mesh pipe axis vs the bubble term's P
        assert plan.pipe_size == cost.pp, s.format()
        # expert group: the mesh expert axis vs the a2a group charged
        assert plan.ep_size == cost.ep, s.format()
        # FSDP collective group: the axes params shard over vs the group
        # the cost model charges AllGather/ReduceScatter for
        fsdp_size = plan.axis_size(plan.fsdp)
        charged = cost.fsdp_n if cost.zero_stage >= 2 else 1
        assert max(fsdp_size, 1) == max(charged, 1), s.format()
        # and the cost report in the ranking priced this exact strategy
        assert p.report.strategy == cost, s.format()


def test_groups_agree_llama_pod():
    _agreement(LLAMA2_7B, POD1, cps=(1, 2, 4, 8), tps=(1, 2, 4, 8, 16))


def test_groups_agree_llama_pod_with_pp():
    _agreement(LLAMA2_7B, POD1, tps=(1, 2, 4), cps=(1, 2),
               pps=(1, 2, 4, 8))


def test_groups_agree_llama_multipod_hsdp():
    # pods=2 exercises the 'pod' axis: dp spans (pod, data), fsdp only data
    _agreement(LLAMA2_7B, POD2, dp_modes=("hsdp", "fsdp"),
               cps=(1, 2, 4), tps=(1, 4, 16))


def test_groups_agree_cp_gt_1_explicit():
    for spec in ("fsdp_cp2", "fsdp_cp4", "hsdp_cp8"):
        s = parse(spec)
        plan = s.to_plan(LLAMA2_7B, POD2, TRAIN, abstract=True)
        cost = s.to_cost_strategy(LLAMA2_7B, POD2)
        assert plan.attn == "context"
        assert cost.cp == s.cp and cost.tp == 1
        assert plan.tp_size == cost.cp
        assert plan.axis_size(plan.dp) == cost.dp


def test_context_fallback_charged_as_cp():
    """tp that can't shard heads lowers as context — and is priced as cp."""
    cfg = get_config("rwkv6-1.6b")
    hybrid = dataclasses.replace(cfg, attn_every=2)  # attention every 2nd
    # pick a tp that divides devices but not heads
    tp = 16
    while hybrid.n_heads % tp == 0:
        tp *= 2
    s = Strategy(dp_mode="fsdp", tp=tp)
    if not s.lowerable(POD1):
        pytest.skip("no viable non-dividing tp on this topology")
    assert s.resolved_attn(hybrid) == "context"
    cost = s.to_cost_strategy(hybrid, POD1)
    assert cost.cp == tp and cost.tp == 1


def test_hsdp_charges_island_group_and_cross_pod_ar():
    s = parse("hsdp_tp4")
    cost = s.to_cost_strategy(LLAMA2_7B, POD2)
    assert cost.fsdp_n == cost.dp // 2          # shard group inside the pod
    r = cm.step_time(LLAMA2_7B, POD2.hw, cost, 256, 4096,
                     hbm_capacity=POD2.hbm)
    assert r.comm_breakdown["hsdp_ar"] > 0      # cross-pod grad all-reduce
    fsdp_cost = parse("fsdp_tp4").to_cost_strategy(LLAMA2_7B, POD2)
    assert fsdp_cost.fsdp_n == fsdp_cost.dp
    r2 = cm.step_time(LLAMA2_7B, POD2.hw, fsdp_cost, 256, 4096,
                      hbm_capacity=POD2.hbm)
    assert r2.comm_breakdown["hsdp_ar"] == 0


# ---------------------------------------------------------------------------
# property tests (hypothesis; skip-stubbed when not installed)
# ---------------------------------------------------------------------------

def _strategy_kwargs():
    return dict(
        dp_mode=st.sampled_from(["hsdp", "fsdp", "ddp"]),
        tp=st.sampled_from([1, 2, 4, 8]),
        cp=st.sampled_from([1, 2, 4]),
        pp=st.sampled_from([1, 2, 4]),
        sched=st.sampled_from(["gpipe", "1f1b", "1f1b_i2", "zb"]),
        ep=st.sampled_from([1, 2, 4, 8]),
        zero_stage=st.sampled_from([None, 0, 2, 3]),
        microbatches=st.sampled_from([1, 4, 8, 16]),
        grad_accum=st.sampled_from([1, 2, 4]),
        attn=st.sampled_from([None, "head_tp", "context"]),
        seq_parallel=st.booleans(),
        overlap=st.booleans(),
    )


def _build(kw):
    try:
        return Strategy(**kw)
    except StrategyError:
        assume(False)


@settings(max_examples=200, deadline=None)
@given(st.fixed_dictionaries(_strategy_kwargs()))
def test_property_spec_round_trip(kw):
    """parse(format(s)) == s for every constructible strategy — including
    the pipeline-schedule token (ISSUE 5 satellite)."""
    s = _build(kw)
    assert parse(s.format()) == s
    # and format is canonical: a second round-trip is a fixed point
    assert parse(s.format()).format() == s.format()


@settings(max_examples=100, deadline=None)
@given(st.fixed_dictionaries(_strategy_kwargs()))
def test_property_group_sizes_match_mesh(kw):
    """For every valid strategy, the collective group sizes the cost model
    is charged equal the mesh axis sizes the lowering builds — dp, model,
    pipe, and (now) expert.  ep > 1 strategies validate against an MoE
    config (ep is rejected for dense models)."""
    s = _build(kw)
    cfg = get_config("deepseek-moe-16b") if kw["ep"] > 1 else LLAMA2_7B
    assume(s.lowerable(POD2, cfg))
    shape = ShapeConfig("prop", 4096,
                        max(256, s.grad_accum * s.microbatches), "train")
    try:
        plan = s.to_plan(cfg, POD2, shape, abstract=True)
        cost = s.to_cost_strategy(cfg, POD2)
    except StrategyError:
        assume(False)
    assert plan.axis_size(plan.dp) == cost.dp, s.format()
    assert plan.tp_size == cost.tp * cost.cp, s.format()
    assert plan.pipe_size == cost.pp, s.format()
    assert plan.ep_size == cost.ep, s.format()
    assert plan.microbatches == (s.microbatches if s.pp > 1 else 1)
    assert plan.pipe_sched == s.sched == cost.sched
    assert plan.zero_overlap == s.overlap == cost.overlap
    if s.ep > 1:
        assert plan.expert in plan.dp      # ep factored out of the data axes
        assert plan.axis_size(plan.dp) == s.dp_effective(POD2) * s.ep


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_search_returns_lowerable_plans_on_host_mesh():
    """Every ranked strategy must actually lower on the host topology."""
    topo = strategy_lib.host_topology()
    cfg = reduced(get_config("qwen3-0.6b"))
    shape = ShapeConfig("host", 64, max(8, topo.n_devices), "train")
    ranked = search(cfg, topo, shape, cps=(1, 2, 4), tps=(1, 2, 4, 8))
    assert ranked
    for p in ranked:
        assert p.lowers
        plan = p.strategy.to_plan(cfg, topo, shape)   # real mesh, must build
        assert plan.mesh.devices.size == topo.n_devices
        # params of the reduced model shard without error
        pshapes = jax.eval_shape(
            lambda: __import__("repro.models.transformer",
                               fromlist=["init_params"]).init_params(
                                   cfg, jax.random.PRNGKey(0)))
        par.param_shardings(cfg, plan, pshapes)


def test_search_rank_and_objectives():
    ranked = search(LLAMA2_7B, POD1, TRAIN, cps=(1, 2, 4))
    scores = [p.score for p in ranked]
    assert scores == sorted(scores, reverse=True)
    assert all(p.report.fits for p in ranked)    # fits-filter applied
    by_energy = search(LLAMA2_7B, POD1, TRAIN, objective="tokens_per_joule")
    assert by_energy[0].report.tokens_per_joule >= \
        by_energy[-1].report.tokens_per_joule
    with pytest.raises(StrategyError):
        search(LLAMA2_7B, POD1, TRAIN, objective="vibes")


def test_search_sweeps_cp_degrees():
    ranked = search(LLAMA2_7B, POD1, TRAIN, cps=(1, 2, 4, 8),
                    require_fits=False)
    assert any(p.strategy.cp > 1 for p in ranked)


def test_search_returns_pp_candidates_by_default():
    """The planner no longer filters pipeline parallelism out of the
    default sweep: pp>1 candidates are ranked and lowerable."""
    ranked = search(LLAMA2_7B, POD1, TRAIN, require_fits=False)
    pp = [p for p in ranked if p.strategy.pp > 1]
    assert pp, "no pp>1 strategies in the default sweep"
    for p in pp:
        assert p.lowers
        assert p.strategy.microbatches >= p.strategy.pp
        plan = p.strategy.to_plan(LLAMA2_7B, POD1, TRAIN, abstract=True)
        assert plan.pipe_size == p.strategy.pp


def test_pp_on_pareto_front_when_node_bandwidth_constrained():
    """The paper's headline crossover: once inter-island bandwidth is
    starved, pipeline parallelism overtakes pure sharded-DP — the planner
    must surface it, not just price it."""
    slow = dataclasses.replace(cm.H100, inter_bw=25e9, alpha_inter=25e-6)
    topo = Topology("slow-fabric", 256, island=8, hardware="H100",
                    hbm=80e9, hw_obj=slow)
    ranked = search(LLAMA2_7B, topo, TRAIN, require_fits=False)
    assert any(p.strategy.pp > 1 for p in ranked)
    front = pareto_front(ranked, objectives=("wps", "tokens_per_joule"))
    assert any(p.strategy.pp > 1 for p in front), \
        [p.spec for p in front]
    # and the pp winner actually beats the best pp=1 point on wps
    best_pp = max(p.score for p in ranked if p.strategy.pp > 1)
    best_flat = max(p.score for p in ranked if p.strategy.pp == 1)
    assert best_pp > best_flat


def test_1f1b_memory_flips_fits_in_planner_sweep():
    """ISSUE 5 acceptance (pinned): the planner sweeps schedules by
    default, and there is a topology where 1F1B's smaller in-flight
    activation footprint flips ``fits`` relative to the same-mesh GPipe
    point — i.e. the schedule choice changes which strategies are
    feasible, exactly the memory-forces-strategy-changes effect the
    paper models."""
    s_g = Strategy(dp_mode="fsdp", pp=4, microbatches=16)
    s_f = dataclasses.replace(s_g, sched="1f1b")
    # long sequences make activations dominate; pick hbm between the two
    # schedules' predicted footprints so the flip is by construction
    shape = ShapeConfig("flip", 16384, 256, "train")
    base = Topology("flip", 256, island=8, hardware="H100", hbm=80e9)
    mem = {s.sched: strategy_lib.evaluate(LLAMA2_7B, s, base, shape)
           .memory_per_device for s in (s_g, s_f)}
    assert mem["1f1b"] < mem["gpipe"]
    topo = dataclasses.replace(base, hbm=(mem["1f1b"] + mem["gpipe"]) / 2)
    r_g = strategy_lib.evaluate(LLAMA2_7B, s_g, topo, shape)
    r_f = strategy_lib.evaluate(LLAMA2_7B, s_f, topo, shape)
    assert r_f.fits and not r_g.fits
    # and the default planner sweep surfaces the 1f1b point as fitting
    # while its gpipe twin is excluded by the fits filter
    ranked = search(LLAMA2_7B, topo, shape, microbatches=16,
                    dp_modes=("fsdp",))
    specs = {p.spec for p in ranked}
    assert s_f.format() in specs, sorted(specs)
    assert s_g.format() not in specs
    assert all(p.report.fits for p in ranked)


def test_overlap_token_flips_fsdp_frontier():
    """ISSUE 10 acceptance (pinned): on an FSDP-bound A100 pod the
    planner's top strategy *changes* when the gather/compute overlap
    token enters the sweep.  Without it, exposed per-layer parameter
    gathers push the winner to tp=2 (smaller gather group per shard);
    with it, the prefetch window hides the gathers and plain fsdp+ovl
    overtakes — the overlap degree moves the frontier, not just a
    number."""
    cfg = get_config("llama2-70b")
    topo = Topology("a100-1024", 1024, island=8, hardware="A100", hbm=80e9)
    shape = ShapeConfig("ovl-flip", 4096, 1024, "train")
    kw = dict(require_lowerable=False, dp_modes=("fsdp",),
              zero_stages=(3,), precisions=("bf16",))
    off = search(cfg, topo, shape, overlaps=(False,), **kw)
    both = search(cfg, topo, shape, **kw)
    assert off[0].spec == "fsdp_tp2_z3_bf16", off[0].spec
    assert both[0].spec == "fsdp_z3_ovl_bf16", both[0].spec
    assert both[0].report.wps > off[0].report.wps
    # the same mesh without the token is strictly slower in the ranking
    by_spec = {p.spec: p for p in both}
    assert by_spec["fsdp_z3_ovl_bf16"].report.t_step < \
        by_spec["fsdp_z3_bf16"].report.t_step


def test_pareto_front_subset_and_contains_best():
    ranked = search(LLAMA2_7B, POD1, TRAIN, require_fits=False)
    front = pareto_front(ranked, objectives=("wps", "tokens_per_joule"))
    specs = {p.spec for p in ranked}
    assert front and {p.spec for p in front} <= specs
    assert ranked[0].spec in {p.spec for p in front}  # wps-best not dominated


def test_resolve_auto_and_spec():
    s, planned = strategy_lib.resolve("auto", LLAMA2_7B, POD1, TRAIN)
    assert planned is not None and planned.strategy == s
    s2, planned2 = strategy_lib.resolve("hsdp_tp4", LLAMA2_7B, POD1, TRAIN)
    assert planned2 is None and s2.tp == 4
    with pytest.raises(StrategyError):
        strategy_lib.resolve("hsdp_tp5", LLAMA2_7B, POD1, TRAIN)


def test_deprecated_shims_removed():
    """ROADMAP: 'remove once no caller remains' — the deprecated
    sweep_strategies/best_strategy and parallel.choose_plan shims are
    gone; the planner is the only strategy-sweep surface."""
    from repro.core import parallel as par_mod
    assert not hasattr(cm, "sweep_strategies")
    assert not hasattr(cm, "best_strategy")
    assert not hasattr(par_mod, "choose_plan")


# ---------------------------------------------------------------------------
# topology / mesh building
# ---------------------------------------------------------------------------

def test_build_mesh_topology_parameterized():
    topo = Topology("t", 512, island=256, hardware="TPUv5e", hbm=16e9)
    m = strategy_lib.build_mesh(topo, model=16, pods=2, abstract=True)
    assert dict(m.shape) == {"pod": 2, "data": 16, "model": 16}
    m1 = strategy_lib.build_mesh(POD1, model=16, abstract=True)
    assert dict(m1.shape) == {"data": 16, "model": 16}
    with pytest.raises(ValueError):
        strategy_lib.build_mesh(POD1, model=5)


def test_get_topology_names():
    assert strategy_lib.get_topology("pod").n_devices == 256
    assert strategy_lib.get_topology("multipod").n_devices == 512
    assert strategy_lib.get_topology("multipod4").n_devices == 1024
    assert strategy_lib.get_topology("host").n_devices == len(jax.devices())
    with pytest.raises(ValueError):
        strategy_lib.get_topology("cluster9000")


def test_decode_cache_axes_long_context():
    s = parse("hsdp_tp16")
    plan = s.to_plan(get_config("qwen3-0.6b"), POD1, SHAPES["long_500k"],
                     abstract=True)
    assert plan.decode_cache_axes == ("data", "model")
