from repro.checkpointing.checkpoint import (AsyncCheckpointer,
                                            CheckpointError,
                                            CheckpointIOError,
                                            gc_checkpoints, latest_step,
                                            latest_valid_step, list_steps,
                                            load_meta, restore_checkpoint,
                                            save_checkpoint, snapshot,
                                            validate_checkpoint,
                                            write_snapshot)

__all__ = [
    "AsyncCheckpointer", "CheckpointError", "CheckpointIOError",
    "gc_checkpoints", "latest_step", "latest_valid_step", "list_steps",
    "load_meta", "restore_checkpoint", "save_checkpoint", "snapshot",
    "validate_checkpoint", "write_snapshot",
]
