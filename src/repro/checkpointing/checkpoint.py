"""Sharded pytree checkpointing without external deps — layout v2.

Layout: ``<dir>/step_<N>/manifest.json`` + one ``.npy`` per leaf, keyed by
the flattened tree path.  Arrays are fetched shard-by-shard
(``jax.device_get``) and restored with ``jax.device_put`` against the
target sharding, so save/restore round-trips across different meshes.

v2 adds the durability pieces a supervisor can trust:

  * **atomic commit** — leaves and manifest are written into
    ``step_<N>.tmp-<token>`` and ``os.replace``d into place, so a crash
    mid-save can never leave a partial ``step_<N>/`` that
    ``latest_step`` would select (the v1 bug: any ``step_*`` dir,
    manifest or not, was eligible);
  * **per-leaf CRC32 checksums** in the manifest, recomputed by
    ``validate_checkpoint`` and (optionally) on restore, so silent
    corruption is detected instead of silently trained on;
  * a ``meta`` sidecar dict in the manifest (training step, PRNG key,
    data-pipeline position) so a resumed run can bit-match an
    uninterrupted one;
  * typed :class:`CheckpointError`\\ s — shape mismatches carry the leaf
    path and both shapes, and missing/extra leaves are aggregated into
    one error instead of failing on the first ``KeyError``;
  * :class:`AsyncCheckpointer` — snapshots leaves to host memory
    on-thread (the only stall the training loop pays) and writes in a
    bounded background thread, committing atomically like the sync path.
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import re
import shutil
import threading
import time
import uuid
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST_VERSION = 2
_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointError(RuntimeError):
    """A checkpoint that cannot be written, read, or trusted."""


class CheckpointIOError(CheckpointError):
    """A (possibly transient) I/O failure in the save/load path."""


def _path_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def _leaf_fname(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"


def _stored_view(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.kind == "V":        # bfloat16 etc: store raw bits
        return arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
    return arr


def _logical_dtype(name: str) -> Optional[np.dtype]:
    """Resolve a manifest dtype name to a numpy dtype — ml_dtypes supplies
    the extended-float families (bfloat16, float8_*) numpy lacks.  None
    for names neither knows."""
    try:
        return np.dtype(name)
    except TypeError:
        pass
    try:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))
    except (ImportError, AttributeError, TypeError):
        return None


def _store_dtype(dtype: np.dtype) -> np.dtype:
    """The on-disk dtype ``_stored_view`` writes for a logical dtype."""
    if dtype.kind == "V":
        return np.dtype(np.uint16 if dtype.itemsize == 2 else np.uint8)
    return dtype


# ---------------------------------------------------------------------------
# snapshot (device -> host) and write (host -> disk), split so the async
# checkpointer can pay only the snapshot on the training thread
# ---------------------------------------------------------------------------

def snapshot(tree: Any) -> Dict[str, Tuple[np.ndarray, str]]:
    """Fetch every leaf to host memory: {path_key: (stored_array, dtype)}.

    ``stored_array`` is the bit-view actually written to disk (bf16 views
    as uint16); ``dtype`` is the logical dtype recorded in the manifest.
    """
    snap: Dict[str, Tuple[np.ndarray, str]] = {}

    def fetch(path, leaf):
        key = _path_key(path)
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(jax.numpy.asarray(leaf).dtype)
        snap[key] = (_stored_view(arr), logical_dtype)
        return leaf

    jax.tree_util.tree_map_with_path(fetch, tree)
    return snap


def write_snapshot(directory: str, step: int,
                   snap: Dict[str, Tuple[np.ndarray, str]],
                   meta: Optional[Dict] = None) -> str:
    """Write a host snapshot to ``step_<N>/`` with an atomic commit."""
    final = _step_dir(directory, step)
    tmp = final + ".tmp-" + uuid.uuid4().hex[:8]
    os.makedirs(tmp, exist_ok=False)
    try:
        leaves = {}
        for key, (arr, logical_dtype) in snap.items():
            fname = _leaf_fname(key)
            np.save(os.path.join(tmp, fname), arr)
            leaves[key] = {"file": fname, "shape": list(arr.shape),
                           "dtype": logical_dtype,
                           "crc32": zlib.crc32(np.ascontiguousarray(arr)
                                               .tobytes())}
        manifest = {"step": step, "version": MANIFEST_VERSION,
                    "leaves": leaves, "meta": meta or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.isdir(final):
            # a previous (necessarily partial or superseded) dir of the
            # same step: replace it wholesale
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def save_checkpoint(directory: str, step: int, tree: Any,
                    meta: Optional[Dict] = None) -> str:
    """Synchronous save: snapshot + atomically committed write."""
    return write_snapshot(directory, step, snapshot(tree), meta)


# ---------------------------------------------------------------------------
# discovery / validation / gc
# ---------------------------------------------------------------------------

def _read_manifest(directory: str, step: int) -> Dict:
    path = os.path.join(_step_dir(directory, step), "manifest.json")
    try:
        with open(path) as f:
            m = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointError(f"unreadable manifest {path}: {e!r}") from e
    if "leaves" not in m:
        raise CheckpointError(f"manifest {path} has no 'leaves' section")
    return m


def list_steps(directory: str) -> List[int]:
    """Steps with a readable manifest, ascending.  ``.tmp-*`` dirs from
    interrupted saves and manifest-less partial dirs are never listed."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if not m:
            continue
        step = int(m.group(1))
        try:
            _read_manifest(directory, step)
        except CheckpointError:
            continue
        steps.append(step)
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    """Newest step whose manifest is readable (a crash mid-save leaves
    either a ``.tmp-*`` dir or nothing — neither is eligible)."""
    steps = list_steps(directory)
    return steps[-1] if steps else None


def validate_checkpoint(directory: str, step: int) -> List[str]:
    """Integrity check: manifest readable, every leaf file present, every
    CRC32 matching.  Returns a list of problems (empty == valid)."""
    try:
        manifest = _read_manifest(directory, step)
    except CheckpointError as e:
        return [str(e)]
    src = _step_dir(directory, step)
    problems = []
    for key, entry in manifest["leaves"].items():
        fpath = os.path.join(src, entry["file"])
        try:
            arr = np.load(fpath)
        except (OSError, ValueError) as e:
            problems.append(f"{key}: unreadable leaf {entry['file']}: {e!r}")
            continue
        if list(arr.shape) != list(entry["shape"]):
            problems.append(f"{key}: stored shape {list(arr.shape)} != "
                            f"manifest shape {entry['shape']}")
        crc = entry.get("crc32")
        if crc is not None and zlib.crc32(
                np.ascontiguousarray(arr).tobytes()) != crc:
            problems.append(f"{key}: CRC32 mismatch in {entry['file']} "
                            "(corrupt leaf)")
    return problems


def latest_valid_step(directory: str, verify: bool = True) -> Optional[int]:
    """Newest step that passes validation; ``verify=True`` recomputes
    CRCs (what the supervisor uses to fall back past corruption),
    ``verify=False`` only requires a readable manifest."""
    for step in reversed(list_steps(directory)):
        if not verify or not validate_checkpoint(directory, step):
            return step
    return None


def gc_checkpoints(directory: str, keep: int = 3) -> List[int]:
    """Delete all but the newest ``keep`` valid checkpoints, plus any
    orphaned ``.tmp-*`` dirs from interrupted saves.  Returns the steps
    removed."""
    if not os.path.isdir(directory):
        return []
    for d in os.listdir(directory):
        if ".tmp-" in d and _STEP_RE.match(d.split(".tmp-")[0]):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    steps = list_steps(directory)
    drop = steps[:-keep] if keep > 0 else []
    for step in drop:
        shutil.rmtree(_step_dir(directory, step), ignore_errors=True)
    return drop


def load_meta(directory: str, step: int) -> Dict:
    """The ``meta`` sidecar recorded at save time ({} for v1 manifests)."""
    return _read_manifest(directory, step).get("meta", {})


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------

def restore_checkpoint(directory: str, step: int, target: Any,
                       shardings: Any = None, verify: bool = False) -> Any:
    """Restore ``target``'s leaves from ``step_<N>/``.

    Raises one aggregated :class:`CheckpointError` naming every missing
    manifest entry, every target leaf absent from the manifest, and every
    shape mismatch (leaf path + stored and target shapes) — instead of
    the v1 behaviour of a bare ``assert``/``KeyError`` on the first
    problem.  ``verify=True`` additionally checks each leaf's CRC32
    before placing it (corruption raises rather than loads).
    """
    src = _step_dir(directory, step)
    manifest = _read_manifest(directory, step)["leaves"]

    target_keys: List[str] = []
    jax.tree_util.tree_map_with_path(
        lambda p, l: target_keys.append(_path_key(p)), target)
    problems: List[str] = []
    missing = sorted(set(target_keys) - set(manifest))
    extra = sorted(set(manifest) - set(target_keys))
    if missing:
        problems.append("target leaves absent from manifest: "
                        + ", ".join(missing))
    if extra:
        problems.append("manifest leaves absent from target: "
                        + ", ".join(extra))
    loaded: Dict[str, np.ndarray] = {}
    for key in target_keys:
        if key not in manifest:
            continue
        entry = manifest[key]
        fpath = os.path.join(src, entry["file"])
        try:
            arr = np.load(fpath)
        except (OSError, ValueError) as e:
            problems.append(f"{key}: unreadable leaf {entry['file']}: {e!r}")
            continue
        if verify and entry.get("crc32") is not None and zlib.crc32(
                np.ascontiguousarray(arr).tobytes()) != entry["crc32"]:
            problems.append(f"{key}: CRC32 mismatch in {entry['file']} "
                            "(corrupt leaf)")
            continue
        if str(arr.dtype) != entry["dtype"]:
            # bit-stored leaf (``_stored_view`` writes bf16/fp8 as
            # uint16/uint8): view back to the exact logical dtype.  Exact
            # comparison, not substring — 'int8' is a substring of
            # 'uint8' and 'float16' of 'bfloat16', so the old
            # ``entry["dtype"] not in str(arr.dtype)`` check silently
            # loaded conflated dtypes without viewing back.
            logical = _logical_dtype(entry["dtype"])
            if logical is None:
                problems.append(f"{key}: unknown manifest dtype "
                                f"{entry['dtype']!r}")
                continue
            if arr.dtype != _store_dtype(logical):
                problems.append(
                    f"{key}: stored dtype {arr.dtype} cannot hold "
                    f"manifest dtype {entry['dtype']}")
                continue
            arr = arr.view(logical)
        loaded[key] = arr

    shape_problems: List[str] = []

    def check_shape(path, leaf):
        key = _path_key(path)
        if key in loaded and tuple(loaded[key].shape) != tuple(leaf.shape):
            shape_problems.append(
                f"{key}: checkpoint shape {tuple(loaded[key].shape)} != "
                f"target shape {tuple(leaf.shape)}")
        return leaf

    jax.tree_util.tree_map_with_path(check_shape, target)
    problems += shape_problems
    if problems:
        raise CheckpointError(
            f"cannot restore step {step} from {directory}:\n  "
            + "\n  ".join(problems))

    def place(path, leaf, shard=None):
        arr = loaded[_path_key(path)]
        if shard is not None:
            return jax.device_put(arr, shard)
        return jax.device_put(arr)

    if shardings is not None:
        return jax.tree_util.tree_map_with_path(place, target, shardings)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: place(p, l), target)


# ---------------------------------------------------------------------------
# async checkpointer
# ---------------------------------------------------------------------------

class AsyncCheckpointer:
    """Background-thread checkpoint writer with bounded in-flight saves.

    ``save`` fetches the leaves to host memory on the calling thread —
    that snapshot (plus any back-pressure wait when ``max_in_flight``
    writes are already queued) is the only stall the training loop pays;
    the ``.npy`` writes, manifest, and atomic commit happen on a single
    background thread, in submission order.  Write errors are re-raised
    on the *next* ``save``/``wait`` call (a background failure must not
    be silently swallowed).

    ``io_error_hook(step)`` is called at the start of each background
    write — the fault-injection seam (``resilience.faults.FaultPlan``
    raises :class:`CheckpointIOError` from it on scheduled steps).
    """

    def __init__(self, directory: str, max_in_flight: int = 2,
                 keep: int = 0,
                 io_error_hook: Optional[Callable[[int], None]] = None):
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, got {max_in_flight}")
        self.directory = directory
        self.keep = keep
        self.io_error_hook = io_error_hook
        self._sem = threading.Semaphore(max_in_flight)
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="ckpt-writer")
        self._pending: List[concurrent.futures.Future] = []
        self._lock = threading.Lock()
        self.stats: List[Dict[str, float]] = []   # one row per save

    def _raise_failed(self) -> None:
        with self._lock:
            done = [f for f in self._pending if f.done()]
            self._pending = [f for f in self._pending if not f.done()]
        for f in done:
            exc = f.exception()
            if exc is not None:
                raise exc

    def save(self, step: int, tree: Any,
             meta: Optional[Dict] = None) -> float:
        """Snapshot on-thread, write in the background; returns the stall
        (snapshot + back-pressure) in seconds."""
        self._raise_failed()
        t0 = time.perf_counter()
        snap = snapshot(tree)
        self._sem.acquire()           # bounds queued writes (back-pressure)
        stall = time.perf_counter() - t0
        fut = self._pool.submit(self._write, step, snap, meta, stall)
        with self._lock:
            self._pending.append(fut)
        return stall

    def _write(self, step, snap, meta, stall) -> str:
        t0 = time.perf_counter()
        try:
            if self.io_error_hook is not None:
                self.io_error_hook(step)
            out = write_snapshot(self.directory, step, snap, meta)
            if self.keep > 0:
                gc_checkpoints(self.directory, keep=self.keep)
        finally:
            self._sem.release()
        self.stats.append({"step": step, "stall_s": stall,
                           "write_s": time.perf_counter() - t0})
        return out

    def wait(self) -> None:
        """Block until every queued write committed; re-raise failures."""
        with self._lock:
            pending = list(self._pending)
        concurrent.futures.wait(pending)
        self._raise_failed()

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
