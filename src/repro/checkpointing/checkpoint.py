"""Sharded pytree checkpointing without external deps.

Layout: ``<dir>/step_<N>/manifest.json`` + one ``.npy`` per leaf, keyed by
the flattened tree path.  Arrays are fetched shard-by-shard
(``jax.device_get``) and restored with ``jax.device_put`` against the
target sharding, so save/restore round-trips across different meshes.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _path_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    out = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(out, exist_ok=True)
    leaves = {}
    def dump(path, leaf):
        key = _path_key(path)
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(jax.numpy.asarray(leaf).dtype)
        if arr.dtype.kind == "V":        # bfloat16 etc: store raw bits
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
        np.save(os.path.join(out, fname), arr)
        leaves[key] = {"file": fname, "shape": list(arr.shape),
                       "dtype": logical_dtype}
        return leaf
    jax.tree_util.tree_map_with_path(dump, tree)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": leaves}, f, indent=1)
    return out


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target: Any,
                       shardings: Any = None) -> Any:
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]

    def load(path, leaf, shard=None):
        key = _path_key(path)
        entry = manifest[key]
        arr = np.load(os.path.join(src, entry["file"]))
        if entry["dtype"] not in str(arr.dtype):   # bit-stored bf16 etc.
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, entry["dtype"])))
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if shard is not None:
            return jax.device_put(arr, shard)
        return jax.device_put(arr)

    if shardings is not None:
        return jax.tree_util.tree_map_with_path(load, target, shardings)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: load(p, l), target)
