"""Pallas TPU chunked WKV-6 kernel (RWKV-6 data-dependent-decay recurrence).

The recurrence S_t = diag(w_t) S_{t-1} + k_t^T v_t, y_t = r_t (S_{t-1} +
(u*k_t)^T v_t) is evaluated in the chunked-parallel form (see
``repro.models.rwkv6.wkv_chunked``): within a chunk of C tokens everything
is dense matmul on the MXU; the (N, N) per-head state is carried across
chunks in VMEM scratch.

Grid: (B*H, n_chunks) — the chunk axis is minormost and therefore
sequential on a TensorCore, exactly what a linear-recurrence scan needs.
VMEM working set per step: 4 x (C, N) inputs + (C, C) scores + (N, N)
state; with C=64, N=64 in fp32 that is ~100 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref, s_scr,
                *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)                    # (C, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                    # (1, N)
    S = s_scr[...]                                      # (N, N)

    lw = jnp.log(jnp.maximum(w, 1e-12))
    lc = jnp.cumsum(lw, axis=0)                         # inclusive
    lc_prev = lc - lw
    qp = r * jnp.exp(lc_prev)
    kp = k * jnp.exp(-lc)

    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (jj < ii).astype(jnp.float32)                 # strictly lower

    A = jax.lax.dot_general(qp, kp, (((1,), (1,)), ((), ()))) * tri
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)    # (C, 1)
    y = jax.lax.dot(A, v) + diag * v + jax.lax.dot(qp, S)

    lc_tot = lc[-1:, :]                                 # (1, N)
    k_tail = k * jnp.exp(lc_tot - lc)
    s_new = jnp.exp(lc_tot).T * S + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())))
    s_scr[...] = s_new

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        sout_ref[0] = s_new.astype(sout_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _wkv6(r, k, v, w, u, chunk, interpret):
    return _wkv6_forward(r, k, v, w, u, chunk, interpret)


def _wkv6_fwd_rule(r, k, v, w, u, chunk, interpret):
    return _wkv6_forward(r, k, v, w, u, chunk, interpret), (r, k, v, w, u)


def _wkv6_bwd_rule(chunk, interpret, res, cts):
    # gradient bridge: the WKV backward is not a Pallas kernel yet, so
    # differentiate the jnp chunked-parallel oracle instead — training with
    # Runtime(attn_impl='pallas') stays end-to-end differentiable and the
    # forward still runs on the kernel.
    from repro.models.rwkv6 import wkv_chunked
    r, k, v, w, u = res
    B, _, H, N = r.shape
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    _, pullback = jax.vjp(
        lambda r, k, v, w, u: wkv_chunked(r, k, v, w, u, s0, chunk),
        r, k, v, w, u)
    return pullback(cts)


_wkv6.defvjp(_wkv6_fwd_rule, _wkv6_bwd_rule)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk=64, interpret=False):
    """r/k/v/w (B,T,H,N), u (H,N) -> (y (B,T,H,N), state (B,H,N,N)).

    Zero initial state (the fused-training entry point; decode keeps the
    recurrent step in plain jnp — it is a single (N,N) mat-vec).
    Differentiable: the backward currently replays the jnp chunked oracle
    (see ``_wkv6_bwd_rule``); a fused Pallas WKV backward is future work.
    """
    return _wkv6(r, k, v, w, u, chunk, interpret)


def _wkv6_forward(r, k, v, w, u, chunk, interpret):
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    Tp = -(-T // chunk) * chunk
    if Tp != T:
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        r, k, v = (jnp.pad(a, pad) for a in (r, k, v))
        w = jnp.pad(w, pad, constant_values=1.0)
    nc = Tp // chunk

    def to_bh(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, Tp, N)

    rb, kb, vb, wb = map(to_bh, (r, k, v, w))
    ub = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N)

    y, s = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk, n_chunks=nc),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, N), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Tp, N), r.dtype),
            jax.ShapeDtypeStruct((B * H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(rb, kb, vb, wb, ub)

    y = y.reshape(B, H, Tp, N).transpose(0, 2, 1, 3)[:, :T]
    return y, s.reshape(B, H, N, N)
