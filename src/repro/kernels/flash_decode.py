"""Pallas TPU flash-decode: GQA split-K attention over a paged KV cache.

Decode attention is memory-bound — one query token against a long KV
context — so the kernel layout follows flash-decode rather than FA2:

  * grid = (B * Kv, n_splits, blocks_per_split).  Each (request, kv-head)
    pair fans out over ``n_splits`` independent K-splits that scan their
    slice of the block table in parallel grid cells; the minormost axis
    walks the KV *blocks* of one split sequentially, carrying the running
    (m, l, acc) online-softmax state in VMEM scratch (same persistent-
    accumulator pattern as ``flash_attention``'s kv axis).
  * the block table and per-request context lengths ride in as *scalar
    prefetch* operands (``PrefetchScalarGridSpec``): the k/v BlockSpec
    index maps read ``tbl[b, s * bps + j]`` to DMA exactly the pool block
    this grid cell needs — the gather lives in the index map, the kernel
    body never sees a pool-sized tensor.
  * each split writes its *partial* (acc, m, l); the host-side wrapper
    merges splits with one logsumexp combine (empty splits carry
    m = -inf, l = 0 and vanish).  GQA comes for free: the G query heads
    that share a kv head form the (G, bs) score tile of one grid cell.

Numerics match ``kernels.ref.paged_attention_ref`` to fp32 round-off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(tbl_ref, ctx_ref, q_ref, k_ref, v_ref,
                   o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr,
                   *, scale, block_size, bps, kv_heads):
    b = pl.program_id(0)                  # request * kv_head
    s = pl.program_id(1)                  # K-split
    j = pl.program_id(2)                  # block within the split (seq.)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bs, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bs)

    # absolute KV positions of this pool block; everything at or past the
    # request's context length is masked (covers tail blocks of the padded
    # table — their clamped gathers contribute nothing)
    n_valid = ctx_ref[b // kv_heads]
    k_pos = (s * bps + j) * block_size + jax.lax.broadcasted_iota(
        jnp.int32, sc.shape, 1)
    mask = k_pos < n_valid
    sc = jnp.where(mask, sc, NEG_INF)

    m_prev = m_scr[...]                                 # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(sc - m_new), 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)

    @pl.when(j == bps - 1)
    def _finalize():
        # partial (unnormalized) outputs: the wrapper's logsumexp combine
        # across splits does the single global normalization
        o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)
        m_ref[0, 0] = m_scr[..., 0]
        l_ref[0, 0] = l_scr[..., 0]


@functools.partial(jax.jit, static_argnames=("n_splits", "interpret"))
def flash_decode(q, k_pool, v_pool, tbl, ctx, *, n_splits=4,
                 interpret=False):
    """q (B, 1, H, D), pools (P, bs, Kv, D), tbl (B, max_blocks) int32,
    ctx (B,) int32 -> (B, 1, H, D).

    tbl entries < 0 (unallocated) are clamped for the gather; their
    positions are >= ctx so the mask removes them.  Full (non-windowed)
    attention only — the jnp paged path handles sliding windows.
    """
    B, Sq, H, D = q.shape
    P, bs, Kv, _ = k_pool.shape
    assert Sq == 1 and H % Kv == 0, (q.shape, Kv)
    G = H // Kv
    nb = tbl.shape[1]

    splits = min(n_splits, nb)
    bps = -(-nb // splits)                  # blocks per split
    nb_pad = splits * bps
    safe_tbl = jnp.clip(tbl, 0, P - 1)
    if nb_pad != nb:                        # padded tail blocks are masked
        safe_tbl = jnp.pad(safe_tbl, ((0, 0), (0, nb_pad - nb)))

    qg = q.reshape(B, Kv, G, D)             # heads grouped by kv head

    kernel = functools.partial(
        _decode_kernel, scale=D ** -0.5, block_size=bs, bps=bps,
        kv_heads=Kv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * Kv, splits, bps),
        # index maps receive (*grid_indices, *scalar_prefetch_refs)
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, s, j, tbl, ctx, Kv=Kv: (b // Kv, b % Kv,
                                                           0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, s, j, tbl, ctx, Kv=Kv, bps=bps:
                         (tbl[b // Kv, s * bps + j], 0, b % Kv, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, s, j, tbl, ctx, Kv=Kv, bps=bps:
                         (tbl[b // Kv, s * bps + j], 0, b % Kv, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, s, j, tbl, ctx: (b, s, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, s, j, tbl, ctx: (b, s, 0)),
            pl.BlockSpec((1, 1, G), lambda b, s, j, tbl, ctx: (b, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * Kv, splits, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B * Kv, splits, G), jnp.float32),
            jax.ShapeDtypeStruct((B * Kv, splits, G), jnp.float32),
        ],
        interpret=interpret,
    )(safe_tbl, ctx, qg, k_pool, v_pool)

    # logsumexp merge across splits: empty splits (m=-inf, l=0) vanish
    m_max = jnp.max(m, axis=1, keepdims=True)            # (B*Kv, 1, G)
    alpha = jnp.exp(m - m_max)                           # (B*Kv, S, G)
    l_tot = jnp.sum(l * alpha, axis=1)                   # (B*Kv, G)
    out = jnp.sum(acc * alpha[..., None], axis=1)        # (B*Kv, G, D)
    out = out / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)
