"""Pallas TPU flash attention (forward + backward): blocked online softmax.

TPU-native design (not a CUDA port, see DESIGN.md §2):
  * forward grid = (batch*kv_heads*q_per_kv, n_q_blocks, n_kv_blocks); the
    minormost kv-block axis executes sequentially on a TensorCore, so the
    running (m, l, acc) state lives in VMEM scratch and is carried across
    kv steps — the TPU analogue of a persistent CTA loop.  The forward also
    emits the per-row logsumexp (lse = m + log l), the only residual the
    backward needs besides q/k/v/o.
  * backward is the standard FA2 two-kernel layout: dq runs q-block-major
    (kv minormost, dq accumulated in VMEM scratch); dk/dv run kv-block-major
    with the (gqa_group, q_block) pair flattened into one sequential axis so
    the dk/dv accumulators also live in scratch and the G query heads that
    share a kv head are reduced on-chip instead of in HBM.  Probabilities
    are recomputed from the saved lse (p = exp(s - lse)) — no S x S tensor
    is ever materialized.
  * BlockSpecs tile q/k/v to (block_q|block_kv, head_dim) VMEM windows;
    block sizes default to 128/256 to keep the MXU's 128-lane shape and a
    working set of ~(2*bq*D + 2*bk*D + bq*bk)*4B well under VMEM.
  * GQA: q heads are grouped by kv head via index_map arithmetic — no
    repeated K/V in HBM.
  * causal + sliding-window masks built from absolute block offsets with
    broadcasted iota (2D, as the TPU requires).

``flash_attention`` carries a ``jax.custom_vjp``, so ``jax.grad`` through it
runs the Pallas backward kernels: the training hot path (fwd + bwd) executes
at kernel speed, which is what makes the cost model's MFU/words-per-second
numbers comparable to measured step times (arXiv 2411.13055 §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_mask(qi, kj, block_q, block_kv, causal, window, seq_len):
    """(block_q, block_kv) visibility for absolute block offsets (qi, kj)."""
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 0)
    k_pos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_kv), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > (q_pos - window)
    return mask


# ---------------------------------------------------------------------------
# forward kernel (emits o and the logsumexp residual)
# ---------------------------------------------------------------------------

def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                  *, scale, block_q, block_kv, n_kv, causal, window, seq_len):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq,bk)

    mask = _block_mask(qi, kj, block_q, block_kv, causal, window, seq_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    # fully-masked rows: make exp(NEG_INF - NEG_INF)=1 contributions vanish
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)

    @pl.when(kj == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(denom))[:, 0]


# ---------------------------------------------------------------------------
# backward kernels (FA2 layout: dq q-block-major; dk/dv kv-block-major)
# ---------------------------------------------------------------------------

def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_scr, *, scale, block_q, block_kv, n_kv,
                         causal, window, seq_len):
    """grid (BH, nq, nk): kv minormost, dq accumulated in VMEM scratch."""
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)                  # (bq, D)
    lse = lse_ref[0].astype(jnp.float32)                # (bq,)
    delta = delta_ref[0].astype(jnp.float32)            # (bq,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _block_mask(qi, kj, block_q, block_kv, causal, window, seq_len)
    # recompute probabilities from the saved logsumexp; masked entries are
    # zeroed explicitly so padded/fully-masked rows (lse == NEG_INF) vanish
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)            # (bq, bk)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))      # (bq, bk)
    ds = p * (dp - delta[:, None])
    dq_scr[...] += jax.lax.dot(ds, k) * scale

    @pl.when(kj == n_kv - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_scr, dv_scr, *, scale, block_q,
                          block_kv, n_q, n_t, causal, window, seq_len):
    """grid (B*Kv, nk, G*nq): the (gqa group, q block) pair is flattened into
    the minormost sequential axis, so dk/dv accumulate across all G query
    heads sharing this kv head without leaving VMEM."""
    kj = pl.program_id(1)
    t = pl.program_id(2)
    qi = t % n_q

    @pl.when(t == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)                  # (bq, D)
    lse = lse_ref[0].astype(jnp.float32)                # (bq,)
    delta = delta_ref[0].astype(jnp.float32)            # (bq,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _block_mask(qi, kj, block_q, block_kv, causal, window, seq_len)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)            # (bq, bk)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None])
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ()))) * scale

    @pl.when(t == n_t - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# host-side plumbing: padding, GQA grouping, pallas_call wiring
# ---------------------------------------------------------------------------

def _dims(q_shape, k_shape, block_q, block_kv):
    B, S, H, D = q_shape
    Kv = k_shape[2]
    assert H % Kv == 0, (H, Kv)
    G = H // Kv
    bq = min(block_q, S)
    Sp = -(-S // bq) * bq
    # bk must divide the padded length exactly or tail blocks are dropped
    # (e.g. S=160 with 128/256 blocks); fall back to bq, which always does
    bk = min(block_kv, Sp)
    if Sp % bk:
        bk = bq
    return B, S, H, D, Kv, G, bq, bk, Sp


def _group_q(x, Kv, G, Sp):
    """(B, S, H, D) -> (B*Kv*G, Sp, D), q heads grouped by kv head."""
    B, S, H, D = x.shape
    if Sp != S:
        x = jnp.pad(x, [(0, 0), (0, Sp - S), (0, 0), (0, 0)])
    return x.reshape(B, Sp, Kv, G, D).transpose(0, 2, 3, 1, 4) \
            .reshape(B * Kv * G, Sp, D)


def _ungroup_q(x, B, Kv, G, S):
    """Inverse of _group_q, dropping padded rows: -> (B, S, Kv*G, D)."""
    _, Sp, D = x.shape
    return x.reshape(B, Kv, G, Sp, D).transpose(0, 3, 1, 2, 4) \
            .reshape(B, Sp, Kv * G, D)[:, :S]


def _group(q, k, v, B, Sp, H, Kv, G, D, S):
    """(B, S, H|Kv, D) -> (B*Kv*G | B*Kv, Sp, D), q heads grouped by kv head."""
    qg = _group_q(q, Kv, G, Sp)
    if Sp != S:
        pad = [(0, 0), (0, Sp - S), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    kg = k.transpose(0, 2, 1, 3).reshape(B * Kv, Sp, D)
    vg = v.transpose(0, 2, 1, 3).reshape(B * Kv, Sp, D)
    return qg, kg, vg


def _flash_forward(q, k, v, causal, window, block_q, block_kv, interpret):
    """-> (out (B,S,H,D), residuals for the backward)."""
    B, S, H, D, Kv, G, bq, bk, Sp = _dims(q.shape, k.shape, block_q, block_kv)
    nq, nk = Sp // bq, Sp // bk
    qg, kg, vg = _group(q, k, v, B, Sp, H, Kv, G, D, S)

    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, block_q=bq, block_kv=bk,
        n_kv=nk, causal=causal, window=window, seq_len=S)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B * Kv * G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Kv * G, Sp, D), q.dtype),
            jax.ShapeDtypeStruct((B * Kv * G, Sp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)

    # residuals keep the grouped/padded layouts: the backward reuses them
    # directly instead of repeating the pad+transpose relayout of q/k/v
    return _ungroup_q(out, B, Kv, G, S), (qg, kg, vg, out, lse)


def _flash_backward(causal, window, block_q, block_kv, interpret, res, g):
    qg, kg, vg, og, lse = res                  # all grouped+padded by the fwd
    B, S, H, D = g.shape
    Kv = kg.shape[0] // B
    _, _, _, _, _, G, bq, bk, Sp = _dims(g.shape, (B, S, Kv, D),
                                         block_q, block_kv)
    nq, nk = Sp // bq, Sp // bk
    dog = _group_q(g, Kv, G, Sp)
    # delta_i = sum_d do_i * o_i — the rowwise correction term of dsoftmax;
    # O(S*D) elementwise, cheaper as one fused jnp reduce than a kernel pass
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), axis=-1)

    scale = D ** -0.5
    dq_kernel = functools.partial(
        _flash_bwd_dq_kernel, scale=scale, block_q=bq, block_kv=bk,
        n_kv=nk, causal=causal, window=window, seq_len=S)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(B * Kv * G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Kv * G, Sp, D), qg.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(qg, kg, vg, dog, lse, delta)

    n_t = G * nq
    dkv_kernel = functools.partial(
        _flash_bwd_dkv_kernel, scale=scale, block_q=bq, block_kv=bk,
        n_q=nq, n_t=n_t, causal=causal, window=window, seq_len=S)
    # q-side blocks walk (group g, q block i) = (t // nq, t % nq)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(B * Kv, nk, n_t),
        in_specs=[
            pl.BlockSpec((1, bq, D),
                         lambda b, j, t, G=G, nq=nq: (b * G + t // nq, t % nq, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),
            pl.BlockSpec((1, bq, D),
                         lambda b, j, t, G=G, nq=nq: (b * G + t // nq, t % nq, 0)),
            pl.BlockSpec((1, bq),
                         lambda b, j, t, G=G, nq=nq: (b * G + t // nq, t % nq)),
            pl.BlockSpec((1, bq),
                         lambda b, j, t, G=G, nq=nq: (b * G + t // nq, t % nq)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, t: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Kv, Sp, D), kg.dtype),
            jax.ShapeDtypeStruct((B * Kv, Sp, D), vg.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        interpret=interpret,
    )(qg, kg, vg, dog, lse, delta)

    dq = _ungroup_q(dq, B, Kv, G, S)
    dk = dk.reshape(B, Kv, Sp, D).transpose(0, 2, 1, 3)[:, :S]
    dv = dv.reshape(B, Kv, Sp, D).transpose(0, 2, 1, 3)[:, :S]
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, block_q, block_kv, interpret):
    out, _ = _flash_forward(q, k, v, causal, window, block_q, block_kv,
                            interpret)
    return out


def _flash_fwd_rule(q, k, v, causal, window, block_q, block_kv, interpret):
    return _flash_forward(q, k, v, causal, window, block_q, block_kv,
                          interpret)


_flash.defvjp(_flash_fwd_rule, _flash_backward)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=128, block_kv=256, interpret=False):
    """q (B,S,H,D), k/v (B,S,Kv,D) -> (B,S,H,D). Self-attention layout.

    Differentiable: ``jax.grad`` runs the Pallas FA2 backward kernels.
    """
    return _flash(q, k, v, causal, window, block_q, block_kv, interpret)
