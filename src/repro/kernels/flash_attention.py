"""Pallas TPU flash attention (forward): blocked online softmax in VMEM.

TPU-native design (not a CUDA port, see DESIGN.md §2):
  * grid = (batch*kv_heads*q_per_kv, n_q_blocks, n_kv_blocks); the minormost
    kv-block axis executes sequentially on a TensorCore, so the running
    (m, l, acc) state lives in VMEM scratch and is carried across kv steps
    — the TPU analogue of a persistent CTA loop.
  * BlockSpecs tile q/k/v to (block_q|block_kv, head_dim) VMEM windows;
    block sizes default to 128/256 to keep the MXU's 128-lane shape and a
    working set of ~(2*bq*D + 2*bk*D + bq*bk)*4B well under VMEM.
  * GQA: q heads are grouped by kv head via index_map arithmetic — no
    repeated K/V in HBM.
  * causal + sliding-window masks built from absolute block offsets with
    broadcasted iota (2D, as the TPU requires).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, block_q, block_kv, n_kv, causal, window, seq_len):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq,bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 0)
    k_pos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_kv), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    # fully-masked rows: make exp(NEG_INF - NEG_INF)=1 contributions vanish
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)

    @pl.when(kj == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=128, block_kv=256, interpret=False):
    """q (B,S,H,D), k/v (B,S,Kv,D) -> (B,S,H,D). Self-attention layout."""
    B, S, H, D = q.shape
    Kv = k.shape[2]
    G = H // Kv
    assert H % Kv == 0, (H, Kv)
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    s_pad = -(-S // max(block_q, block_kv)) * max(block_q, block_kv)
    if s_pad != S:
        pad = [(0, 0), (0, s_pad - S), (0, 0), (0, 0)]
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    Sp = q.shape[1]
    nq, nk = Sp // block_q, Sp // block_kv

    # (B, S, H, D) -> (B*H, S, D) with q heads grouped by kv head
    qg = q.reshape(B, Sp, Kv, G, D).transpose(0, 2, 3, 1, 4) \
          .reshape(B * Kv * G, Sp, D)
    kg = k.transpose(0, 2, 1, 3).reshape(B * Kv, Sp, D)
    vg = v.transpose(0, 2, 1, 3).reshape(B * Kv, Sp, D)

    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, block_q=block_q, block_kv=block_kv,
        n_kv=nk, causal=causal, window=window, seq_len=S)

    out = pl.pallas_call(
        kernel,
        grid=(B * Kv * G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Kv * G, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)

    out = out.reshape(B, Kv, G, Sp, D).transpose(0, 3, 1, 2, 4) \
             .reshape(B, Sp, H, D)
    return out[:, :S]
