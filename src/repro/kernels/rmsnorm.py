"""Pallas TPU fused RMSNorm (forward + backward): one HBM round-trip per
row block.

Rows are tiled (block_rows, d) into VMEM; the mean-square reduction and the
scale multiply fuse in-register (fp32 accumulation regardless of input
dtype).  d is the model dim — a multiple of 128 for every assigned arch,
keeping lanes aligned.

The forward also emits the per-row rstd = rsqrt(mean(x^2) + eps); the fused
backward reuses it (no second reduction over x) and accumulates the
``scale`` gradient across row blocks in a VMEM-resident output block that
the sequential 1-D grid revisits.  ``rmsnorm`` carries a ``jax.custom_vjp``
so training differentiates through the kernel pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, r_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(ms + eps)
    y = x * rstd * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)
    r_ref[...] = rstd[:, 0]


def _rmsnorm_bwd_kernel(x_ref, s_ref, r_ref, g_ref, dx_ref, ds_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        ds_ref[...] = jnp.zeros_like(ds_ref)

    x = x_ref[...].astype(jnp.float32)                  # (rows, d)
    s = s_ref[...].astype(jnp.float32)                  # (d,)
    g = g_ref[...].astype(jnp.float32)                  # (rows, d)
    rstd = r_ref[...][:, None]                          # (rows, 1)

    # y = x * rstd * s; with c = mean(g*s*x) the x-gradient is
    # dx = rstd * (g*s - x * rstd^2 * c) — rstd reused from the forward.
    gs = g * s
    c = jnp.mean(gs * x, axis=-1, keepdims=True)
    dx_ref[...] = (rstd * (gs - x * (rstd * rstd) * c)).astype(dx_ref.dtype)
    ds_ref[...] += jnp.sum(g * x * rstd, axis=0)


def _pad_rows(xf, n, block_rows):
    n_pad = -(-n // block_rows) * block_rows
    if n_pad != n:
        xf = jnp.pad(xf, [(0, n_pad - n), (0, 0)])
    return xf, n_pad


def _rmsnorm_forward(x, scale, eps, block_rows, interpret):
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, n)
    xf, n_pad = _pad_rows(xf, n, block_rows)

    out, rstd = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_pad // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n_pad, d), x.dtype),
                   jax.ShapeDtypeStruct((n_pad,), jnp.float32)],
        interpret=interpret,
    )(xf, scale)
    return out[:n].reshape(shape), (x, scale, rstd)


def _rmsnorm_backward(eps, block_rows, interpret, res, g):
    x, scale, rstd = res                       # rstd already padded (n_pad,)
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    gf = g.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, n)
    xf, n_pad = _pad_rows(xf, n, block_rows)
    gf, _ = _pad_rows(gf, n, block_rows)       # padded rows: x=g=0 -> no-op

    dx, dscale = pl.pallas_call(
        _rmsnorm_bwd_kernel,
        grid=(n_pad // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,)),
                  pl.BlockSpec((block_rows,), lambda i: (i,)),
                  pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                   pl.BlockSpec((d,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n_pad, d), x.dtype),
                   jax.ShapeDtypeStruct((d,), jnp.float32)],
        interpret=interpret,
    )(xf, scale, rstd, gf)
    return dx[:n].reshape(shape), dscale.astype(scale.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _rmsnorm(x, scale, eps, block_rows, interpret):
    out, _ = _rmsnorm_forward(x, scale, eps, block_rows, interpret)
    return out


def _rmsnorm_fwd_rule(x, scale, eps, block_rows, interpret):
    return _rmsnorm_forward(x, scale, eps, block_rows, interpret)


_rmsnorm.defvjp(_rmsnorm_fwd_rule, _rmsnorm_backward)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps=1e-6, block_rows=256, interpret=False):
    """x (..., d), scale (d,) -> rmsnorm(x) * scale.  Differentiable via the
    fused Pallas backward (dx + dscale in one pass)."""
    return _rmsnorm(x, scale, eps, block_rows, interpret)
