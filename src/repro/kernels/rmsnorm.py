"""Pallas TPU fused RMSNorm: one HBM round-trip per row block.

Rows are tiled (block_rows, d) into VMEM; the mean-square reduction and the
scale multiply fuse in-register (fp32 accumulation regardless of input
dtype).  d is the model dim — a multiple of 128 for every assigned arch,
keeping lanes aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps=1e-6, block_rows=256, interpret=False):
    """x (..., d), scale (d,) -> rmsnorm(x) * scale."""
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, n)
    n_pad = -(-n // block_rows) * block_rows
    if n_pad != n:
        xf = jnp.pad(xf, [(0, n_pad - n), (0, 0)])

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_pad // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:n].reshape(shape)
