"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Layouts match the kernels: attention is (B, S, H, D) with GQA via
n_kv_heads | n_heads; wkv6 is (B, T, H, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    """q (B,Sq,H,D), k/v (B,Skv,Kv,D) -> (B,Sq,H,D); fp32 softmax."""
    B, Sq, H, D = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, tbl, ctx, *, window=0):
    """Decode attention over a paged KV cache (fp32 softmax oracle).

    q (B, 1, H, D) one query token per request; k_pool/v_pool
    (P, bs, Kv, D) shared block pools; tbl (B, max_blocks) int32 block
    table (-1 = unallocated); ctx (B,) int32 valid KV positions per
    request (the query sits at position ctx[b] - 1).  Position p of
    request b lives at pool slot (tbl[b, p // bs], p % bs).
    """
    B, Sq, H, D = q.shape
    P, bs, Kv, _ = k_pool.shape
    G = H // Kv
    nb = tbl.shape[1]
    safe = jnp.clip(tbl, 0, P - 1)
    k = k_pool[safe].reshape(B, nb * bs, Kv, D)          # (B, Skv, Kv, D)
    v = v_pool[safe].reshape(B, nb * bs, Kv, D)
    k_pos = jnp.arange(nb * bs)
    valid = (k_pos[None] < ctx[:, None]) & \
        (tbl >= 0).repeat(bs, axis=1)                    # (B, Skv)
    if window:
        valid &= k_pos[None] > (ctx[:, None] - 1 - window)
    qg = q.reshape(B, Sq, Kv, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    s = jnp.where(valid[:, None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def rmsnorm_ref(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def wkv6_ref(r, k, v, w, u, state):
    """Sequential RWKV-6 recurrence (fp32).

    r/k/v/w: (B,T,H,N); u: (H,N); state: (B,H,N,N) mapping key-dim -> val-dim.
    """
    r, k, v, w = (a.astype(jnp.float32) for a in (r, k, v, w))
    state = state.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhn,bhm->bhnm", k_t, v_t)
        y = jnp.einsum("bhn,bhnm->bhm", r_t, S) \
            + jnp.einsum("bhn,bhn,bhm->bhm", r_t, u[None].astype(jnp.float32) * k_t, v_t)
        return w_t[..., None] * S + kv, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state
