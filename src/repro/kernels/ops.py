"""Jit'd public wrappers for the Pallas kernels.

``interpret='auto'`` executes the kernel bodies in Python on CPU (the
validation substrate) and compiles them for real on TPU; the backend probe
is memoized at module level so the hot path never re-queries XLA.  Model
code calls these through ``Runtime.attn_impl == 'pallas'`` /
``Runtime.norm_impl == 'pallas'`` — both forward and backward run as Pallas
kernels (``custom_vjp``), so ``jax.grad`` through a train step stays on the
kernel path.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.rwkv6 import wkv6 as _wkv6

_IS_TPU = None      # memoized jax.default_backend() == 'tpu' probe


def _interp(interpret):
    if interpret == "auto":
        global _IS_TPU
        if _IS_TPU is None:
            _IS_TPU = jax.default_backend() == "tpu"
        return not _IS_TPU
    return bool(interpret)


def _dtype_blocks(dtype, f32_val: int) -> int:
    """Dtype-aware block default: sub-4-byte dtypes double the tile.

    TPU tiling is (8, 128) sublanes x lanes at f32 but (16, 128) at bf16
    — half the bytes per element means a 2x-larger block fills the same
    VMEM footprint while halving grid/loop overhead, which is where the
    bf16 kernels were leaving throughput (BENCH_kernels.json).
    """
    import jax.numpy as jnp
    return f32_val * (2 if jnp.dtype(dtype).itemsize <= 2 else 1)


def attention(q, k, v, *, causal=True, window=0, block_q=None, block_kv=None,
              interpret="auto"):
    if block_q is None:
        block_q = _dtype_blocks(q.dtype, 128)
    if block_kv is None:
        block_kv = _dtype_blocks(q.dtype, 256)
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_kv=block_kv, interpret=_interp(interpret))


def paged_decode_attention(q, k_pool, v_pool, tbl, ctx, *, n_splits=4,
                           interpret="auto"):
    """Flash-decode over a paged KV cache (forward-only; decode has no
    backward).  q (B,1,H,D); pools (P,bs,Kv,D); tbl (B,max_blocks) int32;
    ctx (B,) int32 valid positions per request."""
    return _flash_decode(q, k_pool, v_pool, tbl, ctx, n_splits=n_splits,
                         interpret=_interp(interpret))


def rmsnorm(x, scale, *, eps=1e-6, block_rows=None, interpret="auto"):
    if block_rows is None:
        block_rows = _dtype_blocks(x.dtype, 256)
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                    interpret=_interp(interpret))


def wkv6(r, k, v, w, u, *, chunk=64, interpret="auto"):
    return _wkv6(r, k, v, w, u, chunk=chunk, interpret=_interp(interpret))
