"""Jit'd public wrappers for the Pallas kernels.

``interpret='auto'`` executes the kernel bodies in Python on CPU (the
validation substrate) and compiles them for real on TPU.  Model code calls
these through ``Runtime.attn_impl == 'pallas'``.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.rwkv6 import wkv6 as _wkv6


def _interp(interpret):
    if interpret == "auto":
        return jax.default_backend() != "tpu"
    return bool(interpret)


def attention(q, k, v, *, causal=True, window=0, block_q=128, block_kv=256,
              interpret="auto"):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_kv=block_kv, interpret=_interp(interpret))


def rmsnorm(x, scale, *, eps=1e-6, block_rows=256, interpret="auto"):
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                    interpret=_interp(interpret))


def wkv6(r, k, v, w, u, *, chunk=64, interpret="auto"):
    return _wkv6(r, k, v, w, u, chunk=chunk, interpret=_interp(interpret))
