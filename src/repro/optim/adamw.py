"""AdamW with decoupled weight decay and global-norm gradient clipping.

Optimizer state (m, v) is a pytree congruent with params, so FSDP sharding
rules apply verbatim (ZeRO: optimizer states sharded with the parameters —
this is what makes sharded data parallelism memory-efficient, §2.1 of the
paper).  Moments are kept in fp32 regardless of parameter dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """No weight decay for norms, biases, 1-d params."""
    leaf = getattr(path[-1], "key", getattr(path[-1], "name", str(path[-1])))
    return leaf not in ("scale", "bias", "b_dt", "conv_b", "w0",
                        "maa_x", "maa_k", "maa_r", "bq", "bk", "bv")


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """-> (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path) and p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m2, v2

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t3: t3[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
