from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, global_norm
from repro.optim.schedule import linear_warmup_cosine, constant

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "global_norm",
           "linear_warmup_cosine", "constant"]
