"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(step, warmup: int, total: int, min_ratio: float = 0.1):
    t = step.astype(jnp.float32)
    warm = (t + 1.0) / jnp.maximum(warmup, 1)   # first step lr > 0
    prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(t < warmup, warm, cos)


def constant(step):
    return jnp.ones_like(step, jnp.float32)
