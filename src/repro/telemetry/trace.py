"""Chrome-trace / Perfetto JSON exporter.

Maps the telemetry schema onto the Trace Event Format that Perfetto's
JSON importer (and chrome://tracing) load directly:

* spans      -> complete ('X') events, µs timestamps, one Perfetto
               track per (pid, tid); nesting reconstructs from overlap
* gauges     -> counter ('C') events, one counter track per name
* counters   -> counter ('C') events carrying the running total
* histograms/events -> instant ('i') events so they mark the timeline

Open the file at https://ui.perfetto.dev or chrome://tracing.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, List, Optional

from .sinks import Sink, _jsonable

_US = 1e6  # trace-event timestamps are microseconds


class ChromeTraceSink(Sink):
    """Buffers trace events and writes the JSON document on close."""

    def __init__(self, path: str, pid: int = 1,
                 process_name: str = "repro"):
        self.path = path
        self.pid = pid
        self.process_name = process_name
        self._events: List[Dict] = []
        self._tids_seen: Dict[int, int] = {}
        self._lock = threading.Lock()
        self._closed = False

    def _trace_tid(self, tid: Optional[int]) -> int:
        """Compress python thread idents into small stable track ids."""
        if tid is None:
            tid = 0
        if tid not in self._tids_seen:
            self._tids_seen[tid] = len(self._tids_seen)
        return self._tids_seen[tid]

    def emit(self, event: Dict) -> None:
        kind = event.get("kind")
        name = event.get("name", "?")
        ts_us = float(event.get("ts", 0.0)) * _US
        with self._lock:
            if self._closed:
                return
            if kind == "span":
                ev = {
                    "ph": "X", "name": name,
                    "ts": ts_us,
                    "dur": float(event.get("dur", 0.0)) * _US,
                    "pid": self.pid,
                    "tid": self._trace_tid(event.get("tid")),
                }
                attrs = event.get("attrs")
                if attrs:
                    ev["args"] = attrs
                self._events.append(ev)
            elif kind in ("gauge", "counter"):
                self._events.append({
                    "ph": "C", "name": name, "ts": ts_us,
                    "pid": self.pid, "tid": 0,
                    "args": {"value": event.get("value", 0.0)},
                })
            else:  # histogram observations / structured events
                ev = {
                    "ph": "i", "name": name, "ts": ts_us,
                    "pid": self.pid, "tid": self._trace_tid(
                        event.get("tid")),
                    "s": "t",
                }
                args = {}
                if "value" in event:
                    args["value"] = event["value"]
                if event.get("attrs"):
                    args.update(event["attrs"])
                if args:
                    ev["args"] = args
                self._events.append(ev)

    def _metadata(self) -> List[Dict]:
        meta = [{
            "ph": "M", "name": "process_name", "pid": self.pid, "tid": 0,
            "args": {"name": self.process_name},
        }]
        for ident, tid in sorted(self._tids_seen.items(),
                                 key=lambda kv: kv[1]):
            meta.append({
                "ph": "M", "name": "thread_name",
                "pid": self.pid, "tid": tid,
                "args": {"name": f"host-{tid} ({ident})"},
            })
        return meta

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            doc = {
                "traceEvents": self._metadata() + self._events,
                "displayTimeUnit": "ms",
            }
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        with open(self.path, "w") as f:
            json.dump(doc, f, default=_jsonable)
