"""Predicted-vs-measured drift monitor — the measured half of the
measure↔model calibration loop.

Given the cost model's per-term step-time decomposition for the resolved
Strategy (``StepReport.decomposition()``: seconds per step for
``step``/``compute``/``collective``/``bubble``/...), the monitor takes a
measured decomposition each logging window, computes per-term
``predicted_over_measured`` ratios on the intersecting terms, emits them
as drift gauges, and accumulates windows into a ``results/telemetry/``
artifact that ``benchmarks/run.py --drift-report`` consumes.

A ratio of 1.0 means the model nailed the term; >1 the model
over-predicts (hardware profile too pessimistic for this backend);
<1 it under-predicts.  Terms whose measured value is ~0 get a ``null``
ratio rather than a fabricated number.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from .core import NULL, Recorder

# below this many seconds a measured term is noise, not signal
_MIN_MEASURED_S = 1e-9


class DriftMonitor:
    """Compares one predicted decomposition against measured windows."""

    def __init__(self, predicted: Dict[str, float],
                 telemetry: Recorder = NULL,
                 meta: Optional[Dict] = None):
        self.predicted = {k: float(v) for k, v in predicted.items()}
        self.telemetry = telemetry
        self.meta = dict(meta or {})
        self.windows: List[Dict] = []

    def observe(self, measured: Dict[str, float],
                n_steps: int = 1) -> Dict:
        """Record one window of measured per-step times (seconds).

        ``measured`` maps term name -> mean seconds per step over the
        window.  Returns the window record, including the per-term
        ratio dict (``None`` where a term can't be compared).
        """
        measured = {k: float(v) for k, v in measured.items()}
        ratios: Dict[str, Optional[float]] = {}
        for term in sorted(set(self.predicted) & set(measured)):
            m = measured[term]
            if m <= _MIN_MEASURED_S:
                ratios[term] = None
                continue
            r = self.predicted[term] / m
            ratios[term] = r
            self.telemetry.gauge(
                f"drift/predicted_over_measured/{term}", r)
        window = {
            "window": len(self.windows),
            "n_steps": int(n_steps),
            "predicted": self.predicted,
            "measured": measured,
            "predicted_over_measured": ratios,
        }
        self.windows.append(window)
        return window

    def summary(self) -> Dict:
        """Mean ratio per term across all recorded windows."""
        per_term: Dict[str, List[float]] = {}
        for w in self.windows:
            for term, r in w["predicted_over_measured"].items():
                if r is not None:
                    per_term.setdefault(term, []).append(r)
        return {
            "meta": self.meta,
            "n_windows": len(self.windows),
            "predicted": self.predicted,
            "mean_predicted_over_measured": {
                t: sum(rs) / len(rs) for t, rs in sorted(per_term.items())
            },
        }

    def report(self) -> Dict:
        return {**self.summary(), "windows": self.windows}

    def write(self, path: str) -> Dict:
        """Write the full report JSON (the results/telemetry artifact)."""
        doc = self.report()
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        return doc
