"""The Recorder: spans + metrics fanning out to pluggable sinks.

One Recorder per process (or per test).  ``span(...)`` is a context
manager with thread-local nesting, timed on an injectable monotonic
clock; counters/gauges/histograms live in an attached
:class:`MetricsRegistry` and additionally stream schema events to every
sink, so a JSONL file carries the full story of a run.

When jax is importable, spans also enter
``jax.profiler.TraceAnnotation`` (or ``StepTraceAnnotation`` when the
span carries a ``step_num`` attribute) so the host-side spans line up
with XLA's device traces in a Perfetto view.  The import is lazy and
every failure path degrades to plain host timing — the module stays
zero-dep.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence

from .events import make_event
from .metrics import DEFAULT_BUCKETS, MetricsRegistry
from .sinks import Sink

_PROFILER_UNSET = object()
_jax_profiler = _PROFILER_UNSET


def _profiler():
    """Lazily resolve jax.profiler; None when jax is unavailable."""
    global _jax_profiler
    if _jax_profiler is _PROFILER_UNSET:
        try:
            from jax import profiler  # deferred: keep import cost off tools
            _jax_profiler = profiler
        except Exception:
            _jax_profiler = None
    return _jax_profiler


class _SpanState(threading.local):
    def __init__(self):
        self.stack: List[str] = []


class Recorder:
    """Emits schema events to sinks and aggregates into a registry.

    Parameters
    ----------
    sinks : sinks receiving every event (JSONL, in-memory, Chrome trace)
    clock : monotonic-time source; injectable for deterministic tests
    annotate_jax : wrap spans in jax.profiler annotations when available
    """

    def __init__(self, sinks: Sequence[Sink] = (),
                 clock=time.monotonic,
                 annotate_jax: bool = True):
        self.sinks: List[Sink] = list(sinks)
        self.clock = clock
        self.annotate_jax = annotate_jax
        self.metrics = MetricsRegistry()
        self._span_state = _SpanState()
        self.enabled = True

    # -- plumbing ---------------------------------------------------------

    def add_sink(self, sink: Sink) -> Sink:
        self.sinks.append(sink)
        return sink

    def _emit(self, event: Dict) -> None:
        for sink in self.sinks:
            sink.emit(event)

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()

    # -- spans ------------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, **attrs: Any) -> Iterator[Dict]:
        """Time a block; emits a span event even when the body raises.

        Yields a mutable dict — attributes added to it during the block
        land in the event's ``attrs`` (e.g. ``s["tokens"] = 4096``).
        """
        if not self.enabled:
            yield {}
            return
        stack = self._span_state.stack
        depth = len(stack)
        parent = stack[-1] if stack else None
        stack.append(name)
        ann = None
        prof = _profiler() if self.annotate_jax else None
        if prof is not None:
            try:
                if "step_num" in attrs:
                    ann = prof.StepTraceAnnotation(
                        name, step_num=int(attrs["step_num"]))
                else:
                    ann = prof.TraceAnnotation(name)
                ann.__enter__()
            except Exception:
                ann = None
        t0 = self.clock()
        live_attrs: Dict[str, Any] = dict(attrs)
        try:
            yield live_attrs
        finally:
            dur = max(0.0, self.clock() - t0)
            if ann is not None:
                try:
                    ann.__exit__(None, None, None)
                except Exception:
                    pass
            stack.pop()
            ev = make_event("span", name, t0, dur=dur,
                            tid=threading.get_ident(), depth=depth)
            if parent is not None:
                ev["parent"] = parent
            if live_attrs:
                ev["attrs"] = live_attrs
            self._emit(ev)

    # -- metrics ----------------------------------------------------------

    def counter(self, name: str, delta: float = 1.0,
                **attrs: Any) -> float:
        if not self.enabled:
            return 0.0
        total = self.metrics.counter(name).inc(delta)
        ev = make_event("counter", name, self.clock(),
                        value=total, delta=delta)
        if attrs:
            ev["attrs"] = attrs
        self._emit(ev)
        return total

    def gauge(self, name: str, value: float, **attrs: Any) -> None:
        if not self.enabled:
            return
        self.metrics.gauge(name).set(value)
        ev = make_event("gauge", name, self.clock(), value=float(value))
        if attrs:
            ev["attrs"] = attrs
        self._emit(ev)

    def observe(self, name: str, value: float, n: int = 1,
                buckets=DEFAULT_BUCKETS, **attrs: Any) -> None:
        if not self.enabled:
            return
        self.metrics.histogram(name, buckets).observe(value, n)
        ev = make_event("histogram", name, self.clock(),
                        value=float(value))
        if n != 1:
            ev["n"] = n
        if attrs:
            ev["attrs"] = attrs
        self._emit(ev)

    def event(self, name: str, **attrs: Any) -> Dict:
        """A structured occurrence (supervisor failures, replans, ...)."""
        if not self.enabled:
            return {}
        ev = make_event("event", name, self.clock())
        if attrs:
            ev["attrs"] = attrs
        self._emit(ev)
        return ev


class _NullRecorder(Recorder):
    """A disabled recorder: every operation is a no-op.

    Instrumented call sites take ``telemetry: Recorder = NULL`` so the
    hot paths never branch on ``if telemetry is not None``.
    """

    def __init__(self):
        super().__init__(sinks=(), annotate_jax=False)
        self.enabled = False

    def add_sink(self, sink: Sink) -> Sink:
        raise RuntimeError("cannot attach sinks to the null recorder; "
                           "construct a Recorder instead")


NULL = _NullRecorder()
