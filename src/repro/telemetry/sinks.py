"""Pluggable sinks for telemetry events.

A sink is anything with ``emit(event: dict)`` and ``close()``; the
Recorder fans every event out to all attached sinks.  The Chrome-trace
exporter lives in :mod:`repro.telemetry.trace`.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, List, Optional


class Sink:
    def emit(self, event: Dict) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def close(self) -> None:
        pass


class InMemorySink(Sink):
    """Buffers every event; handy for tests and post-run summaries."""

    def __init__(self):
        self.events: List[Dict] = []
        self._lock = threading.Lock()

    def emit(self, event: Dict) -> None:
        with self._lock:
            self.events.append(event)

    def by_kind(self, kind: str) -> List[Dict]:
        with self._lock:
            return [e for e in self.events if e.get("kind") == kind]

    def by_name(self, name: str) -> List[Dict]:
        with self._lock:
            return [e for e in self.events if e.get("name") == name]


class JsonlSink(Sink):
    """Appends one JSON object per line; the shared on-disk format.

    This is the writer behind both ``--metrics_jsonl`` and the
    supervisor's event log — one schema, one serializer.  Lines are
    flushed per event so a crashed run still leaves a readable stream.
    """

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")
        self._lock = threading.Lock()

    def emit(self, event: Dict) -> None:
        line = json.dumps(event, default=_jsonable)
        with self._lock:
            if self._f is None:
                return
            self._f.write(line + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


def _jsonable(obj):
    """Last-resort coercion for numpy/jax scalars in event payloads."""
    for attr in ("item",):
        fn = getattr(obj, attr, None)
        if callable(fn):
            try:
                return fn()
            except Exception:
                pass
    return str(obj)
