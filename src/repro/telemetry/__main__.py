"""Schema-check CLI: validate telemetry artifacts.

    python -m repro.telemetry results/telemetry events.jsonl trace.json

Directories are scanned recursively for ``*.jsonl`` event streams and
``*trace*.json`` Chrome traces; exits non-zero on any schema violation.
CI runs this over everything the benchmark job emitted.
"""
from __future__ import annotations

import argparse
import sys

from .events import check_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="Validate telemetry JSONL / Chrome-trace artifacts.")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to validate")
    args = ap.parse_args(argv)
    n_files, n_events, errs = check_paths(args.paths)
    for e in errs:
        print(f"SCHEMA ERROR: {e}", file=sys.stderr)
    print(f"telemetry schema check: {n_files} files, {n_events} events, "
          f"{len(errs)} errors")
    if n_files == 0:
        print("no telemetry artifacts found", file=sys.stderr)
        return 1
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
