"""Counters, gauges, and histograms with exact percentiles.

Histograms keep two representations: fixed log-spaced buckets for cheap
export/merging, and the raw observations for *exact* nearest-rank
percentiles (the p50/p99 the serving benchmarks report).  Retaining raw
values is deliberate — windows here are bounded (a logging window, a
benchmark run), so memory is not a concern and exactness beats the
usual streaming sketch.

Zero-dep and thread-safe (one lock per instrument).
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

# Default export buckets: log-spaced from 1µs to ~100s, suited to both
# per-token latencies (~ms) and step times (~s).
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    10.0 ** (e / 4.0) for e in range(-24, 9)
)


def percentile(values: Sequence[float], q: float) -> float:
    """Exact nearest-rank percentile (q in [0, 100]) of ``values``.

    This is the oracle definition the tests pin: for n values sorted
    ascending, p_q = sorted[ceil(q/100 * n) - 1] (and the minimum for
    q = 0).  Raises ValueError on an empty sequence.
    """
    if not values:
        raise ValueError("percentile of empty sequence")
    s = sorted(values)
    if q <= 0:
        return s[0]
    rank = math.ceil(q / 100.0 * len(s))
    return s[min(rank, len(s)) - 1]


class Counter:
    """A monotonically increasing count."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, delta: float = 1.0) -> float:
        with self._lock:
            self.value += delta
            return self.value

    def snapshot(self) -> Dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """A point-in-time reading; remembers only the latest value."""

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None
        self._lock = threading.Lock()

    def set(self, value: float) -> float:
        with self._lock:
            self.value = float(value)
            return self.value

    def snapshot(self) -> Dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram that also retains raw observations.

    ``bucket_counts[i]`` counts observations <= ``buckets[i]``; the last
    slot is the +inf overflow.  Percentiles come from the raw values via
    :func:`percentile`, so they are exact, not interpolated.
    """

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.buckets: Tuple[float, ...] = tuple(sorted(buckets))
        self.bucket_counts: List[int] = [0] * (len(self.buckets) + 1)
        self.values: List[float] = []
        self.sum = 0.0
        self._lock = threading.Lock()

    @property
    def count(self) -> int:
        return len(self.values)

    def observe(self, value: float, n: int = 1) -> None:
        value = float(value)
        with self._lock:
            idx = bisect.bisect_left(self.buckets, value)
            self.bucket_counts[idx] += n
            self.values.extend([value] * n)
            self.sum += value * n

    def percentile(self, q: float) -> float:
        with self._lock:
            return percentile(self.values, q)

    def snapshot(self) -> Dict:
        with self._lock:
            out = {
                "type": "histogram",
                "count": len(self.values),
                "sum": self.sum,
            }
            if self.values:
                out["mean"] = self.sum / len(self.values)
                out["min"] = min(self.values)
                out["max"] = max(self.values)
                out["p50"] = percentile(self.values, 50)
                out["p90"] = percentile(self.values, 90)
                out["p99"] = percentile(self.values, 99)
            # only the occupied buckets, to keep snapshots readable
            nz = {}
            for i, c in enumerate(self.bucket_counts):
                if c:
                    le = self.buckets[i] if i < len(self.buckets) else "inf"
                    nz[str(le)] = c
            out["buckets"] = nz
            return out


class MetricsRegistry:
    """Named instruments, created on first use, snapshottable at once."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, *args)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, buckets)

    def snapshot(self) -> Dict[str, Dict]:
        with self._lock:
            items = list(self._instruments.items())
        return {name: inst.snapshot() for name, inst in sorted(items)}
