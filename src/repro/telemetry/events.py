"""The telemetry event schema: one flat dict per emitted record.

Every sink in this package — the JSONL stream, the in-memory recorder,
the Chrome-trace exporter — speaks the same schema, and the CI
schema-check validates every JSONL line a run emits against it:

    {"ts": <float seconds>,        # recorder clock (monotonic by default)
     "kind": "span" | "counter" | "gauge" | "histogram" | "event",
     "name": <str>,                # hierarchical, '/'-separated
     ...kind-specific fields}

Kind-specific fields:

* ``span``      — ``dur`` (seconds, >= 0), ``tid`` (int thread id),
                  ``depth`` (int nesting level), optional ``attrs``;
                  ``ts`` is the span *start*.
* ``counter``   — ``value`` (the running total after the increment) and
                  ``delta`` (this increment).
* ``gauge``     — ``value`` (the new reading).
* ``histogram`` — ``value`` (one observation), optional ``n`` (weight).
* ``event``     — a structured occurrence (e.g. a supervisor failure);
                  payload under ``attrs``.

Zero-dependency on purpose: no jax, no numpy — importable from any
process that only wants to validate or post-process artifacts.
"""
from __future__ import annotations

import glob
import json
import numbers
import os
from typing import Any, Dict, Iterable, List, Tuple

EVENT_KINDS = ("span", "counter", "gauge", "histogram", "event")

# required non-ts fields per kind (ts/kind/name are required everywhere)
_REQUIRED: Dict[str, Tuple[str, ...]] = {
    "span": ("dur",),
    "counter": ("value",),
    "gauge": ("value",),
    "histogram": ("value",),
    "event": (),
}


def make_event(kind: str, name: str, ts: float, **fields: Any) -> Dict:
    """Build one schema-conforming event (validated at construction)."""
    ev = {"ts": float(ts), "kind": kind, "name": name, **fields}
    errs = validate_event(ev)
    if errs:
        raise ValueError(f"invalid telemetry event {ev!r}: {errs}")
    return ev


def validate_event(ev: Any) -> List[str]:
    """Return the list of schema violations (empty = valid)."""
    errs: List[str] = []
    if not isinstance(ev, dict):
        return [f"event is {type(ev).__name__}, not a dict"]
    if not isinstance(ev.get("ts"), numbers.Real):
        errs.append("missing/non-numeric 'ts'")
    kind = ev.get("kind")
    if kind not in EVENT_KINDS:
        errs.append(f"'kind' {kind!r} not in {EVENT_KINDS}")
    name = ev.get("name")
    if not isinstance(name, str) or not name:
        errs.append("missing/empty 'name'")
    for field in _REQUIRED.get(kind, ()):
        if not isinstance(ev.get(field), numbers.Real):
            errs.append(f"span/metric field {field!r} missing or non-numeric")
    if kind == "span" and isinstance(ev.get("dur"), numbers.Real) \
            and ev["dur"] < 0:
        errs.append(f"negative span dur {ev['dur']}")
    attrs = ev.get("attrs")
    if attrs is not None and not isinstance(attrs, dict):
        errs.append("'attrs' must be a dict when present")
    return errs


def validate_jsonl(path: str) -> Tuple[int, List[str]]:
    """Validate every line of a JSONL event file.

    Returns ``(n_events, errors)`` where each error names its line.
    """
    n, errs = 0, []
    try:
        f = open(path)
    except OSError as e:
        return 0, [f"{path}: unreadable ({e})"]
    with f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            n += 1
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errs.append(f"{path}:{i}: not JSON ({e})")
                continue
            for msg in validate_event(ev):
                errs.append(f"{path}:{i}: {msg}")
    return n, errs


def validate_chrome_trace(path: str) -> Tuple[int, List[str]]:
    """Validate a Chrome-trace/Perfetto JSON file's structure.

    Checks exactly what Perfetto's JSON importer needs: a top-level
    ``traceEvents`` list whose entries have ``ph``/``name``, with complete
    ('X') events carrying numeric ``ts``/``dur`` and a ``pid``/``tid``.
    """
    errs: List[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return 0, [f"{path}: unreadable ({e})"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return 0, [f"{path}: no 'traceEvents' list"]
    for i, ev in enumerate(evs):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(ev, dict) or "ph" not in ev:
            errs.append(f"{where}: missing 'ph'")
            continue
        if not isinstance(ev.get("name"), str):
            errs.append(f"{where}: missing 'name'")
        if ev["ph"] == "X":
            for field in ("ts", "dur"):
                if not isinstance(ev.get(field), numbers.Real):
                    errs.append(f"{where}: 'X' event needs numeric {field!r}")
            if isinstance(ev.get("dur"), numbers.Real) and ev["dur"] < 0:
                errs.append(f"{where}: negative dur")
            for field in ("pid", "tid"):
                if field not in ev:
                    errs.append(f"{where}: missing {field!r}")
    return len(evs), errs


def summarize_events(events: Iterable[Dict]) -> Dict:
    """Aggregate a supervisor-style event list into the summary document
    the ``--event_log`` flag has always written (the pinned resilience
    tests read these exact keys)."""
    events = list(events)
    failures = [e for e in events if e.get("kind") == "failure"]
    return {
        "n_failures": len(failures),
        "total_lost_steps": sum(e.get("lost_steps") or 0 for e in failures),
        "total_recovery_s": sum(e.get("recovery_wall_s") or 0.0
                                for e in failures),
        "events": events,
    }


def check_paths(paths: Iterable[str]) -> Tuple[int, int, List[str]]:
    """Validate every telemetry artifact under ``paths``.

    Directories are scanned for ``*.jsonl`` (event streams) and
    ``*trace*.json`` (Chrome traces).  Returns
    ``(n_files, n_events, errors)``.
    """
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            files += sorted(glob.glob(os.path.join(p, "**", "*.jsonl"),
                                      recursive=True))
            files += sorted(glob.glob(os.path.join(p, "**", "*trace*.json"),
                                      recursive=True))
        else:
            files.append(p)
    n_events, errs = 0, []
    for path in files:
        if path.endswith(".jsonl"):
            n, e = validate_jsonl(path)
        else:
            n, e = validate_chrome_trace(path)
        n_events += n
        errs += e
    return len(files), n_events, errs
