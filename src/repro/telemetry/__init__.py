"""Unified telemetry: structured spans + metrics, pluggable sinks,
Chrome-trace/Perfetto export, and the predicted-vs-measured
DriftMonitor.

Typical wiring (what ``launch/train.py --trace --metrics_jsonl`` does):

    from repro import telemetry
    rec = telemetry.Recorder()
    rec.add_sink(telemetry.JsonlSink("events.jsonl"))
    rec.add_sink(telemetry.ChromeTraceSink("trace.json"))
    with rec.span("train/step", step_num=i):
        ...
    rec.close()   # flushes the trace JSON

``telemetry.NULL`` is a disabled recorder — instrumented call sites
default to it so un-instrumented runs pay (almost) nothing.
"""
from .core import NULL, Recorder
from .drift import DriftMonitor
from .events import (EVENT_KINDS, check_paths, make_event,
                     summarize_events, validate_chrome_trace,
                     validate_event, validate_jsonl)
from .metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, percentile)
from .sinks import InMemorySink, JsonlSink, Sink
from .trace import ChromeTraceSink

__all__ = [
    "NULL", "Recorder", "DriftMonitor",
    "EVENT_KINDS", "make_event", "summarize_events", "check_paths",
    "validate_event", "validate_jsonl", "validate_chrome_trace",
    "DEFAULT_BUCKETS", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "percentile",
    "Sink", "InMemorySink", "JsonlSink", "ChromeTraceSink",
]
