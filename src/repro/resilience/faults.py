"""Deterministic fault injection for the training loop.

At the scales the paper studies, failures stop being rare events: with a
per-device MTBF of weeks, a 10k-device job sees one every few hours, and
lost work + restart time become a first-order throughput term (the
``costmodel.goodput`` model prices exactly that).  This module makes the
*recovery machinery* testable on a CPU host: a :class:`FaultPlan` is a
seeded, step-indexed schedule of

  * **crashes** — raised as :class:`SimulatedFailure` at the top of the
    scheduled step, before any work for that step runs (so "steps
    completed" is exactly the failing step index), optionally carrying a
    lost-device count for elastic re-planning;
  * **stragglers** — per-step wall-clock delay multipliers, applied as a
    host-side sleep scaled by the measured step time (the
    thermal-throttling / power-capping slowdown mode);
  * **transient checkpoint-I/O errors** — a per-step failure budget
    consumed by ``ckpt_io_check``, raised as
    :class:`~repro.checkpointing.CheckpointIOError` until the budget for
    that step is spent (a retry then succeeds — transient by
    construction).

Plans are value objects: ``generate(seed, ...)`` is deterministic (same
seed -> same schedule), and ``to_json``/``from_json`` round-trip so a CLI
run can pin its schedule in an artifact (``--fault_plan plan.json``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

from repro.checkpointing import CheckpointIOError

FAULT_KINDS = ("crash", "straggler", "ckpt_io")


class SimulatedFailure(RuntimeError):
    """An injected device/host crash (the supervisor's retry trigger)."""

    def __init__(self, step: int, lost_devices: int = 0,
                 detail: str = ""):
        self.step = step
        self.lost_devices = lost_devices
        msg = f"simulated failure at step {step}"
        if lost_devices:
            msg += f" ({lost_devices} device(s) lost)"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault."""
    step: int
    kind: str                 # 'crash' | 'straggler' | 'ckpt_io'
    magnitude: float = 1.0    # straggler: slowdown multiplier (>= 1);
    #                           ckpt_io: number of failing attempts
    lost_devices: int = 0     # crash: devices lost (0 = process crash only)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"kind {self.kind!r} not in {FAULT_KINDS}")


@dataclasses.dataclass
class FaultPlan:
    """A step-indexed fault schedule, plus mutable retry bookkeeping."""
    events: List[FaultEvent] = dataclasses.field(default_factory=list)
    seed: Optional[int] = None
    # crash steps already raised once are not re-raised on the restarted
    # attempt (a real crashed host does not re-crash deterministically at
    # the same step after replacement) — the supervisor's resume path
    # would otherwise never make progress past a scheduled step
    _fired: set = dataclasses.field(default_factory=set, repr=False)
    _io_spent: Dict[int, int] = dataclasses.field(default_factory=dict,
                                                  repr=False)

    # ---- construction ------------------------------------------------------

    @classmethod
    def generate(cls, seed: int, n_steps: int,
                 crash_rate: float = 0.0,
                 straggler_rate: float = 0.0,
                 straggler_slowdown: float = 2.0,
                 ckpt_io_rate: float = 0.0) -> "FaultPlan":
        """Sample a schedule: each step independently draws each fault
        kind at its rate.  Deterministic in ``seed`` (one substream per
        fault kind, so changing one rate never reshuffles the others)."""
        events: List[FaultEvent] = []
        for kind, rate in (("crash", crash_rate),
                           ("straggler", straggler_rate),
                           ("ckpt_io", ckpt_io_rate)):
            rng = np.random.default_rng([seed, FAULT_KINDS.index(kind)])
            draws = rng.random(n_steps)
            for step in np.nonzero(draws < rate)[0]:
                if kind == "crash":
                    events.append(FaultEvent(int(step), "crash",
                                             lost_devices=0))
                elif kind == "straggler":
                    events.append(FaultEvent(int(step), "straggler",
                                             magnitude=straggler_slowdown))
                else:
                    events.append(FaultEvent(int(step), "ckpt_io",
                                             magnitude=1.0))
        events.sort(key=lambda e: (e.step, FAULT_KINDS.index(e.kind)))
        return cls(events=events, seed=seed)

    @classmethod
    def crashes_at(cls, *steps: int, lost_devices: int = 0) -> "FaultPlan":
        """Explicit crash schedule (the unit-test workhorse)."""
        return cls(events=[FaultEvent(s, "crash", lost_devices=lost_devices)
                           for s in sorted(steps)])

    # ---- serialization -----------------------------------------------------

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "events": [dataclasses.asdict(e) for e in self.events]},
            indent=1)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        return cls(events=[FaultEvent(**e) for e in d.get("events", [])],
                   seed=d.get("seed"))

    # ---- queries -----------------------------------------------------------

    def _at(self, step: int, kind: str) -> Optional[FaultEvent]:
        for e in self.events:
            if e.step == step and e.kind == kind:
                return e
        return None

    def crash_steps(self) -> List[int]:
        return sorted(e.step for e in self.events if e.kind == "crash")

    def delay_multiplier(self, step: int) -> float:
        """Straggler slowdown for this step (1.0 = no fault)."""
        e = self._at(step, "straggler")
        return max(e.magnitude, 1.0) if e else 1.0

    # ---- injection hooks (called by the training loop) ---------------------

    def check_crash(self, step: int) -> None:
        """Raise :class:`SimulatedFailure` if a crash is scheduled at
        ``step`` and has not fired yet (each crash fires once)."""
        e = self._at(step, "crash")
        if e is not None and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(step, lost_devices=e.lost_devices,
                                   detail="injected by FaultPlan")

    def ckpt_io_check(self, step: int) -> None:
        """Raise :class:`CheckpointIOError` while the scheduled failing-
        attempt budget for ``step`` is unspent; later attempts succeed
        (this is the *transient* I/O error mode — a retry recovers)."""
        e = self._at(step, "ckpt_io")
        if e is None:
            return
        spent = self._io_spent.get(step, 0)
        if spent < int(e.magnitude):
            self._io_spent[step] = spent + 1
            raise CheckpointIOError(
                f"injected transient checkpoint-I/O failure at step {step} "
                f"(attempt {spent + 1}/{int(e.magnitude)})")

    def reset(self) -> None:
        """Forget retry bookkeeping (a fresh supervisor run replays the
        full schedule)."""
        self._fired.clear()
        self._io_spent.clear()


def load_fault_plan(spec: str) -> FaultPlan:
    """CLI entry: a path to a ``to_json`` file, or an inline spec
    ``crash@<step>[,<step>...]`` for quick experiments."""
    if spec.startswith("crash@"):
        steps = [int(s) for s in spec[len("crash@"):].split(",") if s]
        return FaultPlan.crashes_at(*steps)
    with open(spec) as f:
        return FaultPlan.from_json(f.read())
