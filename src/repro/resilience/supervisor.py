"""Elastic restart supervisor: retry, restore, re-plan, record.

The supervisor owns the outermost loop of a fault-tolerant run.  One
*attempt* is a full ``train_loop`` invocation (with ``tc.resume=True`` so
each attempt restores from the newest CRC-valid checkpoint); the
supervisor catches :class:`~repro.resilience.faults.SimulatedFailure`
(and real exceptions), applies exponential backoff under a max-restart
budget, optionally **re-plans the strategy for a degraded device count**
(a crash reporting lost devices shrinks the topology and asks the
planner for the best strategy that still lowers — the data/fsdp axis
absorbs the loss), and records a structured event log (failures,
restarts, lost steps, recovery wall time) that the dryrun/benchmark
artifacts fold in.

The supervisor is deliberately generic over the attempt body: ``run``
drives any ``attempt_fn(attempt, strategy, topology) -> result``, so
tests can exercise backoff/budget/fallback logic without a real model,
and :func:`supervise_training` provides the production wiring used by
``launch/train.py``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

from repro.resilience.faults import SimulatedFailure
from repro import telemetry as tel


class RestartBudgetExceeded(RuntimeError):
    """More failures than ``max_restarts`` allows; the last cause chains."""


@dataclasses.dataclass
class SupervisorConfig:
    max_restarts: int = 3
    backoff_base_s: float = 0.05      # first restart waits this long
    backoff_factor: float = 2.0       # then base * factor**n, capped
    backoff_max_s: float = 5.0
    replan_on_degrade: bool = True    # lost devices -> planner re-pick
    event_log_path: str = ""          # write the structured log here


class Supervisor:
    """Retry loop with backoff, checkpoint fallback, and elastic re-plan."""

    def __init__(self, config: SupervisorConfig, ckpt_dir: str = "",
                 telemetry: tel.Recorder = tel.NULL):
        self.config = config
        self.ckpt_dir = ckpt_dir
        self.telemetry = telemetry
        self.events: List[Dict[str, Any]] = []

    # ---- bookkeeping -------------------------------------------------------

    def _record(self, **kw) -> Dict[str, Any]:
        event = {"t": time.time(), **kw}
        self.events.append(event)
        self.telemetry.counter(f"supervisor/{kw.get('kind', 'event')}", 1)
        return event

    def backoff_s(self, n_restarts: int) -> float:
        c = self.config
        return min(c.backoff_base_s * c.backoff_factor ** n_restarts,
                   c.backoff_max_s)

    def restore_step(self) -> Optional[int]:
        """Newest CRC-valid checkpoint step (corrupt/partial skipped)."""
        if not self.ckpt_dir:
            return None
        from repro import checkpointing as ckpt_lib
        return ckpt_lib.latest_valid_step(self.ckpt_dir, verify=True)

    def write_event_log(self) -> Optional[str]:
        """Write the summary JSON at ``event_log_path`` (the pinned
        ``--event_log`` format) plus a sibling ``.jsonl`` carrying the
        same events in the shared telemetry schema, written by the
        telemetry JSONL sink — one serializer for every event stream in
        the repo.  Emission happens here, not in ``_record``, because
        the retry loop keeps mutating failure events (backoff_s,
        recovery_wall_s, budget_exhausted) after recording them."""
        path = self.config.event_log_path
        if not path:
            return None
        out_dir = os.path.dirname(path)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(tel.summarize_events(self.events), f, indent=1)
        sink = tel.JsonlSink(os.path.splitext(path)[0] + ".jsonl")
        try:
            for e in self.events:
                attrs = {k: v for k, v in e.items()
                         if k not in ("t", "kind") and v is not None}
                ev = tel.make_event(
                    "event", f"supervisor/{e.get('kind', 'event')}",
                    e["t"])
                if attrs:
                    ev["attrs"] = attrs
                sink.emit(ev)
        finally:
            sink.close()
        return path

    # ---- elastic re-plan ---------------------------------------------------

    def degrade(self, cfg, strategy, topology, shape, lost_devices: int):
        """Shrink the topology by the lost devices and re-plan.

        The surviving count is rounded down to a multiple of the current
        model-parallel footprint (the data/fsdp axis is what shrinks —
        the model axes must stay whole), then the planner picks the best
        strategy that still lowers there.  Returns (strategy, topology);
        falls back to the current pair when nothing viable survives.
        """
        from repro.strategy import best
        n = topology.n_devices - lost_devices
        mp = strategy.model_parallel
        n -= n % mp
        if n < mp:
            return strategy, topology
        topo2 = dataclasses.replace(topology, name=topology.name + "-deg",
                                    n_devices=n,
                                    island=min(topology.island, n))
        planned = best(cfg, topo2, shape)
        if planned is None:
            return strategy, topology
        return planned.strategy, topo2

    # ---- driver ------------------------------------------------------------

    def run(self, attempt_fn: Callable[[int, Any, Any], Any],
            strategy: Any = None, topology: Any = None,
            cfg: Any = None, shape: Any = None) -> Any:
        """Drive ``attempt_fn`` to completion under the restart budget.

        ``attempt_fn(attempt, strategy, topology)`` runs one attempt; the
        strategy/topology pair evolves across attempts when a failure
        reports lost devices and re-planning is on.  Raises
        :class:`RestartBudgetExceeded` (chaining the last cause) once
        ``max_restarts`` restarts are spent.
        """
        n_restarts = 0
        while True:
            t_start = time.time()
            try:
                with self.telemetry.span("supervisor/attempt",
                                         attempt=n_restarts):
                    result = attempt_fn(n_restarts, strategy, topology)
                self._record(kind="completed", attempt=n_restarts,
                             n_restarts=n_restarts)
                self.write_event_log()
                return result
            except (SimulatedFailure, Exception) as e:  # noqa: BLE001
                t_fail = time.time()
                step_failed = getattr(e, "step", None)
                lost = getattr(e, "lost_devices", 0)
                restore = self.restore_step()
                event = self._record(
                    kind="failure", attempt=n_restarts,
                    error=repr(e),
                    simulated=isinstance(e, SimulatedFailure),
                    step_failed=step_failed,
                    restore_step=restore,
                    lost_steps=(step_failed - (restore or 0)
                                if step_failed is not None else None),
                    lost_devices=lost,
                    run_wall_s=round(t_fail - t_start, 4))
                if n_restarts >= self.config.max_restarts:
                    event["budget_exhausted"] = True
                    self.write_event_log()
                    raise RestartBudgetExceeded(
                        f"{n_restarts + 1} failures exceed "
                        f"max_restarts={self.config.max_restarts} "
                        f"(last: {e!r})") from e
                backoff = self.backoff_s(n_restarts)
                event["backoff_s"] = backoff
                if backoff:
                    time.sleep(backoff)
                if lost and self.config.replan_on_degrade and \
                        cfg is not None and topology is not None:
                    old_spec = strategy.format() if strategy is not None \
                        else None
                    strategy, topology = self.degrade(
                        cfg, strategy, topology, shape, lost)
                    self._record(kind="replan", attempt=n_restarts,
                                 lost_devices=lost,
                                 old_spec=old_spec,
                                 new_spec=strategy.format(),
                                 n_devices=topology.n_devices)
                n_restarts += 1
                event["recovery_wall_s"] = round(time.time() - t_fail, 4)


def supervise_training(cfg, strategy, topology, shape, tc, make_batches,
                       rt_overrides: Optional[Dict] = None, key=None,
                       fault_plan=None,
                       sup_cfg: Optional[SupervisorConfig] = None,
                       telemetry: tel.Recorder = tel.NULL, drift=None):
    """Production wiring: supervised ``train_loop`` attempts.

    Each attempt rebuilds the plan/runtime/data from the (possibly
    re-planned) strategy and topology and runs with ``tc.resume=True``,
    so a restart restores the newest valid checkpoint and replays the
    data stream from the restored position.  ``make_batches()`` must
    return a *fresh* batch iterable per call (sources are stateful).
    Returns ``(params, opt_state, history, supervisor)``.
    """
    import jax

    from repro.core import parallel as par
    from repro.train.trainer import train_loop

    sup = Supervisor(sup_cfg or SupervisorConfig(), ckpt_dir=tc.ckpt_dir,
                     telemetry=telemetry)

    def attempt(n_restarts, strat, topo):
        plan = strat.to_plan(cfg, topo, shape)
        rt = par.make_runtime(cfg, plan, shape, **(rt_overrides or {}))
        tc_run = dataclasses.replace(tc, resume=tc.resume or n_restarts > 0)
        return train_loop(cfg, plan, rt, tc_run, make_batches(),
                          key=key if key is not None
                          else jax.random.PRNGKey(0),
                          fault_plan=fault_plan,
                          telemetry=telemetry, drift=drift)

    params, opt_state, history = sup.run(
        attempt, strategy=strategy, topology=topology, cfg=cfg, shape=shape)
    return params, opt_state, history, sup
