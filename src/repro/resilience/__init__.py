from repro.resilience.faults import (FaultEvent, FaultPlan, SimulatedFailure,
                                     load_fault_plan)
from repro.resilience.supervisor import (RestartBudgetExceeded, Supervisor,
                                         SupervisorConfig)

__all__ = [
    "FaultEvent", "FaultPlan", "SimulatedFailure", "load_fault_plan",
    "RestartBudgetExceeded", "Supervisor", "SupervisorConfig",
]
