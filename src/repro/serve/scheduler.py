"""Continuous-batching scheduler: admission, chunked prefill, completion.

The engine runs in *ticks*.  Each tick the scheduler:

  1. **admits** waiting requests FIFO into free batch slots, reserving
     their full block footprint (padded prompt + new tokens + one step of
     headroom) up front — all-or-nothing reservation means a running
     request can never fail an allocation mid-flight, and strict FIFO
     admission (the head of the queue blocks the tail) means no request
     starves behind later, smaller ones;
  2. advances every admitted request with prompt tokens left by one
     **prefill chunk** (oldest first), so long prompts never monopolize
     a tick yet same-age requests enter decode together instead of
     trickling in one tick apart behind full-cost decode segments; and
  3. reports the set of **decode-active** slots for the engine's
     on-device decode segment.

Completion (token budget exhausted) returns the request's blocks to the
:class:`~repro.serve.paged_cache.BlockAllocator` and frees its slot, so
the next waiting request joins the running batch on the following tick.

Requests can also leave early: a per-request **TTL** (``submit(...,
ttl_s=...)``) expires the request once its deadline passes — whether it
is still waiting or mid-generation — and ``cancel(rid)`` removes one
explicitly.  Both paths free blocks+slot exactly like completion and
record why in ``Request.finish_reason`` ('length' | 'timeout' |
'cancelled'), so a client that stops listening cannot pin KV blocks
forever and a stuck head-of-queue request cannot starve the tail
indefinitely.  Time comes from an injectable ``clock`` (tests pass a
fake; production defaults to ``time.monotonic``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.paged_cache import BlockAllocator
from repro import telemetry as tel


@dataclasses.dataclass
class Request:
    """One generation request and its in-flight state."""
    rid: int
    prompt: np.ndarray                  # (S0,) int32
    n_new: int
    temperature: float = 0.0
    # sampling-stream id: the PRNG key for the token at position p is
    # fold_in(fold_in(base_key, stream), p).  Defaults to rid (every
    # request draws an independent stream); callers wanting reproducible
    # batches across engine lifetimes pin it explicitly.
    stream: int = -1
    # scheduler-owned runtime state
    slot: int = -1                      # batch slot (-1 = not admitted)
    blocks: List[int] = dataclasses.field(default_factory=list)
    prefilled: int = 0                  # prompt tokens written so far
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    deadline: float = 0.0               # absolute clock time; 0 = no TTL
    finish_reason: str = ""             # 'length' | 'timeout' | 'cancelled'
    # lifecycle timestamps on the scheduler clock (0.0 = not reached):
    # queued -> admitted -> first token -> finished
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_finish: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prompt_len

    @property
    def remaining(self) -> int:
        return self.n_new - len(self.generated)


class Scheduler:
    def __init__(self, n_slots: int, allocator: BlockAllocator,
                 prefill_chunk: int = 32, steps_per_tick: int = 8,
                 clock: Callable[[], float] = time.monotonic,
                 telemetry: tel.Recorder = tel.NULL):
        self.n_slots = n_slots
        self.alloc = allocator
        self.prefill_chunk = prefill_chunk
        self.steps_per_tick = steps_per_tick
        self.clock = clock
        self.telemetry = telemetry
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}       # slot -> request
        self.finished: Dict[int, Request] = {}      # rid -> request
        self._next_rid = 0

    # finish_reason -> lifecycle counter name
    _FINISH_COUNTERS = {"length": "serve/completed",
                        "timeout": "serve/expired",
                        "cancelled": "serve/cancelled"}

    def _finish(self, req: Request, reason: str) -> None:
        """Shared finish bookkeeping: timestamps + lifecycle telemetry."""
        req.done = True
        req.finish_reason = reason
        req.t_finish = self.clock()
        self.finished[req.rid] = req
        self.telemetry.counter(
            self._FINISH_COUNTERS.get(reason, "serve/completed"), 1)
        if req.t_submit:
            self.telemetry.observe("serve/total_latency_s",
                                   req.t_finish - req.t_submit)

    # -- submission / bookkeeping -------------------------------------------

    def submit(self, prompt: np.ndarray, n_new: int,
               temperature: float = 0.0, stream: Optional[int] = None,
               ttl_s: float = 0.0) -> int:
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        self.waiting.append(Request(rid, np.asarray(prompt, np.int32),
                                    n_new, temperature,
                                    stream=rid if stream is None else stream,
                                    deadline=(now + ttl_s
                                              if ttl_s > 0 else 0.0),
                                    t_submit=now))
        self.telemetry.counter("serve/submitted", 1)
        self.telemetry.gauge("serve/queue_depth", len(self.waiting))
        return rid

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def _footprint(self, req: Request) -> int:
        """Blocks reserved at admission: the prompt padded to a whole
        number of prefill chunks (pad tokens of the last chunk write
        beyond the real prompt before being overwritten), the new tokens,
        and one decode step of headroom (an inactive slot in a running
        segment writes one sentinel position past its budget)."""
        chunks = -(-req.prompt_len // self.prefill_chunk)
        return self.alloc.blocks_for(
            chunks * self.prefill_chunk + req.n_new + 1)

    def admit(self) -> List[Request]:
        """FIFO admission into free slots; head-of-line blocking on
        purpose (skipping the head to admit a smaller later request is
        what starves big requests)."""
        admitted = []
        free = sorted(set(range(self.n_slots)) - set(self.running))
        while self.waiting and free:
            req = self.waiting[0]
            blocks = self.alloc.allocate(self._footprint(req))
            if blocks is None:
                break
            req.blocks = blocks
            req.slot = free.pop(0)
            self.running[req.slot] = req
            admitted.append(self.waiting.pop(0))
            req.t_admit = self.clock()
            self.telemetry.counter("serve/admitted", 1)
            self.telemetry.observe("serve/queue_wait_s",
                                   req.t_admit - req.t_submit)
        if admitted:
            self.telemetry.gauge("serve/queue_depth", len(self.waiting))
        return admitted

    # -- per-tick work selection --------------------------------------------

    def prefill_candidates(self) -> List[Request]:
        """Admitted requests with prompt tokens still to write, oldest
        first.  The engine feeds each one chunk per tick: a single long
        prompt still spreads over many ticks (bounded per-tick stall),
        but concurrent prompts prefill in the same tick rather than
        serializing one request per tick."""
        cands = [r for r in self.running.values() if not r.prefill_done]
        return sorted(cands, key=lambda r: r.rid)

    def next_prefill(self) -> Optional[Request]:
        """Oldest admitted request with prompt tokens still to write."""
        cands = self.prefill_candidates()
        return cands[0] if cands else None

    def decode_slots(self) -> List[Request]:
        return [r for r in self.running.values()
                if r.prefill_done and r.remaining > 0]

    def complete(self, req: Request, reason: str = "length") -> None:
        """Request leaving the running set: free blocks and slot."""
        assert req.slot in self.running and self.running[req.slot] is req
        del self.running[req.slot]
        self.alloc.free(req.blocks)
        req.blocks = []
        req.slot = -1
        self._finish(req, reason)

    # -- early exit: TTL expiry and explicit cancellation -------------------

    def _retire_waiting(self, req: Request, reason: str) -> None:
        self.waiting.remove(req)
        self._finish(req, reason)
        self.telemetry.gauge("serve/queue_depth", len(self.waiting))

    def expire(self, now: Optional[float] = None) -> List[Tuple[int, Request]]:
        """Retire every request whose deadline has passed.

        Covers both the running set (blocks + slot freed like completion)
        and the waiting queue — an expired head-of-queue request must not
        keep blocking admission of the tail forever.  Returns
        ``(slot, request)`` pairs — slot is the seat the request *held*
        (-1 if never admitted) so the engine can clear its block-table
        row; the request keeps whatever tokens it generated.
        """
        now = self.clock() if now is None else now
        expired: List[Tuple[int, Request]] = []
        for req in [r for r in self.running.values()
                    if r.deadline and now >= r.deadline]:
            slot = req.slot
            self.complete(req, reason="timeout")
            expired.append((slot, req))
        for req in [r for r in self.waiting
                    if r.deadline and now >= r.deadline]:
            self._retire_waiting(req, "timeout")
            expired.append((-1, req))
        return expired

    def cancel(self, rid: int) -> Optional[Tuple[int, Request]]:
        """Explicitly remove one request, waiting or running.

        Returns ``(slot, request)`` with the seat it held (-1 if it was
        still waiting), or None if the rid is unknown / already finished
        (cancelling a finished request is a no-op, not an error).
        """
        for req in self.running.values():
            if req.rid == rid:
                slot = req.slot
                self.complete(req, reason="cancelled")
                return slot, req
        for req in self.waiting:
            if req.rid == rid:
                self._retire_waiting(req, "cancelled")
                return -1, req
        return None
