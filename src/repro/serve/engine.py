"""Serving engine: paged KV cache, on-device decode loop, continuous batching.

Two execution paths:

  * **paged / continuous** (the production path, single-device attention
    stacks): requests enter through ``submit`` and are drained by
    ``run_until_drained``.  Prefill is *chunked* (one chunk per tick) into
    a shared block pool via per-request block tables
    (``serve/paged_cache.py``); decode runs as a jitted
    ``lax.fori_loop`` *segment* of ``steps_per_tick`` tokens — sampling
    happens inside the loop, so the host dispatches once per segment
    instead of once per token (the orchestration-overhead term the paper
    shows dominating when per-step compute shrinks).  The
    ``serve/scheduler.py`` tick model lets requests join and leave the
    running batch at segment boundaries.
  * **static batch** (``generate_static``): the seed's host-dispatched
    per-token loop over the dense seq_len-sized cache.  Kept as the
    numerical baseline (paged greedy decode must bit-match it) and for
    sharded plans / hybrid (RWKV/Mamba) stacks, which keep dense caches.

``generate`` stays the compatibility entry point: it routes through the
request queue when the paged path applies and falls back to the static
loop otherwise.

Determinism contract: the token sampled at absolute position ``p`` of
a request on sampling stream ``s`` (= its request id unless pinned at
``submit``; ``generate`` pins the batch row index) uses
``fold_in(fold_in(base_key, s), p)`` — independent
of batch composition, tick boundaries, and chunk sizes, so a generation
is reproducible across scheduler layouts given the same ``base_key``.
``base_key`` is the explicit ``key=`` argument when given; otherwise it
is derived from ``ServeEngine.seed`` *and a per-call counter* — repeated
``generate`` calls draw fresh samples instead of silently reusing
``PRNGKey(0)`` (the seed engine's bug), and reproducibility is opt-in via
``key=`` or a fresh engine.

``make_serve_step`` is the function the decode-shape dry-runs lower:
(params, cache, tokens, pos) -> (logits, cache'), one new token per request
against a seq_len-sized KV/state cache.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import parallel as par
from repro.models import transformer as tfm
from repro.models.layers import Runtime
from repro.serve.paged_cache import BlockAllocator, init_paged_pools
from repro.serve.scheduler import Scheduler
from repro import telemetry as tel

# sentinel context for slots that must not write this step: the block
# lookup lands past every table and the write is dropped
_INACTIVE_POS = jnp.int32(1 << 30)


def make_serve_step(cfg: ModelConfig, rt: Runtime):
    def serve_step(params, cache, tokens, pos):
        logits, cache = tfm.decode_step(cfg, params, cache, tokens, pos, rt)
        return logits, cache
    return serve_step


def make_prefill(cfg: ModelConfig, rt: Runtime, max_len: int):
    def prefill_fn(params, batch):
        return tfm.prefill(cfg, params, batch, rt, max_len)
    return prefill_fn


@dataclasses.dataclass
class ServeEngine:
    """Batched generation over the public model API.

    ``n_slots`` bounds the in-flight batch; ``block_size`` is the paged-
    cache granularity; ``n_blocks=0`` sizes the pool so every slot can
    hold ``max_len`` context.  ``prefill_chunk`` / ``steps_per_tick`` set
    the tick shape (one prefill chunk per prefilling request and one
    decode segment per tick).
    """
    cfg: ModelConfig
    params: Any
    rt: Runtime
    max_len: int
    plan: Optional[par.ParallelPlan] = None
    seed: int = 0
    n_slots: int = 8
    block_size: int = 16
    n_blocks: int = 0
    prefill_chunk: int = 32
    steps_per_tick: int = 8
    # telemetry: per-request lifecycle (queued -> prefill -> decode) with
    # queue-wait/TTFT/per-token latency histograms, tick-level
    # batch-occupancy and block-pool gauges.  ``clock`` is injectable so
    # tests pin latency math exactly.
    telemetry: tel.Recorder = tel.NULL
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill(self.cfg, self.rt, self.max_len))
        self._step = jax.jit(make_serve_step(self.cfg, self.rt))
        self._calls = 0
        cfg = self.cfg
        self.paged_ok = (
            self.plan is None and cfg.input_mode == "tokens" and
            all(cfg.layer_kind(i) == "attn" for i in range(cfg.n_layers)))
        self._paged_cache = None
        if self.paged_ok:
            self._max_blocks = BlockAllocator(1, self.block_size).blocks_for(
                self.max_len + self.prefill_chunk + 1)
            if not self.n_blocks:
                self.n_blocks = self.n_slots * self._max_blocks
            self._prefill_chunk_fn = jax.jit(self._paged_prefill_chunk)
            self._segment_fn = jax.jit(self._paged_decode_segment,
                                       static_argnames=("steps",))
            self._reset_queue()

    # ------------------------------------------------------------------
    # request-queue API (paged continuous batching)
    # ------------------------------------------------------------------

    def _reset_queue(self):
        self._sched = Scheduler(
            self.n_slots, BlockAllocator(self.n_blocks, self.block_size),
            prefill_chunk=self.prefill_chunk,
            steps_per_tick=self.steps_per_tick,
            clock=self.clock, telemetry=self.telemetry)
        if self._paged_cache is None:
            self._paged_cache = init_paged_pools(
                self.cfg, self.n_blocks, self.block_size,
                self.rt.compute_dtype, self.rt)
        self._tbl = np.full((self.n_slots, self._max_blocks), -1, np.int32)
        self._ctx = np.zeros((self.n_slots,), np.int32)
        self._last = np.zeros((self.n_slots,), np.int32)
        self._temps = np.zeros((self.n_slots,), np.float32)
        self._streams = np.zeros((self.n_slots,), np.int32)

    def submit(self, prompt, n_new: int, temperature: float = 0.0,
               stream: Optional[int] = None, ttl_s: float = 0.0) -> int:
        """Enqueue one request; returns its request id.  ``stream``
        selects the sampling stream (see module docstring); it defaults
        to the request id.  ``ttl_s`` > 0 sets a deadline after which the
        request is retired with finish_reason='timeout' (partial output
        kept, KV blocks freed) whether it is waiting or mid-generation."""
        if not self.paged_ok:
            raise RuntimeError(
                "request-queue serving needs the paged cache path "
                "(single-device plan, attention-only stack, token inputs); "
                "use generate()/generate_static() instead")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.shape[0] + n_new > self.max_len:
            raise ValueError(
                f"prompt({prompt.shape[0]}) + n_new({n_new}) exceeds "
                f"max_len({self.max_len})")
        return self._sched.submit(prompt, n_new, temperature, stream=stream,
                                  ttl_s=ttl_s)

    def cancel(self, rid: int) -> bool:
        """Cancel one request (waiting or running).  Frees its seat and
        KV blocks; partial output stays available under finish_reason
        'cancelled'.  Returns False for unknown/finished rids."""
        out = self._sched.cancel(rid)
        if out is None:
            return False
        slot, _ = out
        if slot >= 0:
            self._tbl[slot] = -1
        return True

    def _base_key(self, key=None):
        if key is not None:
            return key
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), self._calls)
        self._calls += 1
        return key

    def _token_key(self, base_key, stream: int, pos: int):
        return jax.random.fold_in(jax.random.fold_in(base_key, stream), pos)

    def _sample_host(self, base_key, stream, pos, logits, temperature):
        lg = jnp.asarray(logits, jnp.float32)
        if temperature > 0:
            return int(jax.random.categorical(
                self._token_key(base_key, stream, pos), lg / temperature))
        return int(jnp.argmax(lg))

    def run_until_drained(self, key=None) -> Dict[int, np.ndarray]:
        """Tick until every submitted request completed; returns
        {rid: generated tokens (n_new,)}."""
        base_key = self._base_key(key)
        sched = self._sched
        while sched.has_work():
            self._tick(base_key)
        out = {r.rid: np.asarray(r.generated, np.int32)
               for r in sched.finished.values()}
        sched.finished.clear()
        return out

    def _tick(self, base_key):
        sched = self._sched
        with self.telemetry.span("serve/tick"):
            # expire first: a timed-out running request frees its seat
            # before admission, and a timed-out waiting request stops
            # blocking the queue head this same tick
            for slot, _ in sched.expire():
                if slot >= 0:
                    self._tbl[slot] = -1
            for req in sched.admit():
                # lay the reserved block chain into the slot's table row
                self._tbl[req.slot] = -1
                self._tbl[req.slot, :len(req.blocks)] = req.blocks
                self._ctx[req.slot] = 0
                self._temps[req.slot] = req.temperature
                self._streams[req.slot] = req.stream
            for req in sched.prefill_candidates():
                self._do_prefill_chunk(base_key, req)
            active = sched.decode_slots()
            if active:
                self._do_decode_segment(base_key, active)
            for req in list(sched.running.values()):
                if req.prefill_done and req.remaining <= 0:
                    self._tbl[req.slot] = -1
                    sched.complete(req)
            self.telemetry.gauge("serve/batch_occupancy",
                                 len(sched.running) / self.n_slots)
            self.telemetry.gauge(
                "serve/block_util",
                1.0 - sched.alloc.n_free / max(self.n_blocks, 1))
        if (req is None and not active and sched.waiting
                and not sched.running):
            raise RuntimeError(
                "scheduler stalled: waiting requests cannot be admitted "
                f"(pool of {self.n_blocks} blocks too small?)")

    def _cache_dict(self):
        return {**self._paged_cache,
                "paged": {"tbl": jnp.asarray(self._tbl),
                          "ctx": jnp.asarray(self._ctx)}}

    def _store_pools(self, cache):
        self._paged_cache = {"prefix": cache["prefix"],
                             "blocks": cache["blocks"]}

    def _do_prefill_chunk(self, base_key, req):
        C = self.prefill_chunk
        start = req.prefilled
        chunk = req.prompt[start:start + C]
        real = int(chunk.shape[0])
        if real < C:
            chunk = np.pad(chunk, (0, C - real))
        t0 = self.clock()
        with self.telemetry.span("serve/prefill_chunk", rid=req.rid,
                                 start=start, n=real):
            logits, cache = self._prefill_chunk_fn(
                self.params, self._cache_dict(), jnp.asarray(chunk[None]),
                jnp.int32(req.slot), jnp.int32(start))
            self._store_pools(cache)
            req.prefilled = start + real
            self._ctx[req.slot] = req.prefilled
            if req.prefill_done and req.remaining > 0:
                # the last real prompt token's logits give the first
                # sampled token, at absolute position prompt_len
                tok = self._sample_host(base_key, req.stream,
                                        req.prompt_len,
                                        logits[real - 1], req.temperature)
                req.generated.append(tok)
                self._last[req.slot] = tok
                req.t_first_token = self.clock()
                if req.t_submit:
                    self.telemetry.observe(
                        "serve/ttft_s", req.t_first_token - req.t_submit)
        self.telemetry.observe("serve/prefill_chunk_s",
                               self.clock() - t0)

    def _observe_token_latency(self, wall: float, n_tokens: int) -> None:
        """Per-token latency over a decode segment: the tick's wall time
        amortized across every token it delivered (each of the n tokens
        experienced the same segment wait)."""
        if n_tokens > 0 and wall >= 0:
            self.telemetry.observe("serve/token_latency_s",
                                   wall / n_tokens, n=n_tokens)

    def _do_decode_segment(self, base_key, active):
        steps = self.steps_per_tick
        remaining = np.zeros((self.n_slots,), np.int32)
        for req in active:
            remaining[req.slot] = req.remaining
        t0 = self.clock()
        with self.telemetry.span("serve/decode_segment", steps=steps,
                                 n_active=len(active)):
            cache, seg_out = self._segment_fn(
                self.params, self._cache_dict(), jnp.asarray(self._last),
                jnp.asarray(remaining), jnp.asarray(self._streams),
                jnp.asarray(self._temps), base_key, steps=steps)
            self._store_pools(cache)
            seg_out = np.asarray(seg_out)   # forces the device sync
        delivered = 0
        for req in active:
            n = min(req.remaining, steps)
            toks = seg_out[req.slot, :n]
            req.generated.extend(int(t) for t in toks)
            self._ctx[req.slot] += n
            delivered += n
            if n:
                self._last[req.slot] = int(toks[-1])
        self._observe_token_latency(self.clock() - t0, delivered)

    # ------------------------------------------------------------------
    # jitted paged bodies
    # ------------------------------------------------------------------

    def _paged_prefill_chunk(self, params, cache, tokens, slot, ctx0):
        """One prompt chunk (1, C) of one slot through the model, writing
        its KV into the slot's block chain; returns the chunk logits
        (C, V) and the updated cache."""
        paged = cache["paged"]
        tbl_row = jax.lax.dynamic_slice_in_dim(paged["tbl"], slot, 1, 0)
        view = {"prefix": cache["prefix"], "blocks": cache["blocks"],
                "paged": {"tbl": tbl_row, "ctx": ctx0[None]}}
        batch = {"tokens": tokens, "pos": jnp.reshape(ctx0, (1, 1))}
        logits, newc, _ = tfm.forward(self.cfg, params, batch, self.rt,
                                      cache=view)
        cache = {"prefix": newc["prefix"], "blocks": newc["blocks"],
                 "paged": paged}
        return logits[0], cache

    def _paged_decode_segment(self, params, cache, last, remaining,
                              streams, temps, base_key, *, steps: int):
        """``steps`` decode iterations entirely on device: forward one
        token per slot, sample in-loop (greedy where temperature == 0,
        categorical otherwise, keyed by (stream, position)), advance
        active slot's context.  Slots with remaining == 0 (empty,
        each active slot's context.  Slots with remaining == 0 ride
        along with their writes dropped and outputs masked to 0."""
        cfg, rt = self.cfg, self.rt
        paged = cache["paged"]
        pools = {"prefix": cache["prefix"], "blocks": cache["blocks"]}
        B = last.shape[0]

        def body(t, carry):
            pools, ctx, last, remaining, out = carry
            active = remaining > 0
            ctx_eff = jnp.where(active, ctx, _INACTIVE_POS)
            cdict = {**pools, "paged": {"tbl": paged["tbl"], "ctx": ctx_eff}}
            logits, newc, _ = tfm.forward(
                cfg, params, {"tokens": last[:, None], "pos": ctx_eff[:, None]},
                rt, cache=cdict)
            lg = logits[:, 0].astype(jnp.float32)
            pos_new = ctx + 1
            keys = jax.vmap(functools.partial(self._token_key, base_key))(
                streams, pos_new)
            sampled = jax.vmap(
                lambda k, l, T: jax.random.categorical(
                    k, l / jnp.maximum(T, 1e-6)))(keys, lg, temps)
            greedy = jnp.argmax(lg, axis=-1)
            nxt = jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
            nxt = jnp.where(active, nxt, 0)
            out = out.at[:, t].set(nxt)
            last = jnp.where(active, nxt, last)
            ctx = ctx + active.astype(jnp.int32)
            remaining = remaining - active.astype(jnp.int32)
            pools = {"prefix": newc["prefix"], "blocks": newc["blocks"]}
            return pools, ctx, last, remaining, out

        out0 = jnp.zeros((B, steps), jnp.int32)
        pools, ctx, last, remaining, out = jax.lax.fori_loop(
            0, steps, body, (pools, paged["ctx"], last, remaining, out0))
        cache = {**pools, "paged": {"tbl": paged["tbl"], "ctx": ctx}}
        return cache, out

    # ------------------------------------------------------------------
    # batch entry points
    # ------------------------------------------------------------------

    def generate(self, prompts: jnp.ndarray, n_new: int,
                 temperature: float = 0.0, key=None) -> jnp.ndarray:
        """prompts: (B, S0) int32 -> (B, S0 + n_new).

        Routes through the paged continuous-batching queue when it
        applies (see module docstring); falls back to the static dense-
        cache loop for sharded plans and hybrid stacks.
        """
        B, S0 = prompts.shape
        assert S0 + n_new <= self.max_len
        if not self.paged_ok:
            return self.generate_static(prompts, n_new, temperature, key)
        prompts_np = np.asarray(prompts, np.int32)
        # stream = row index: the same (prompts, key) pair reproduces
        # the same tokens regardless of prior engine traffic
        rids = [self.submit(prompts_np[i], n_new, temperature, stream=i)
                for i in range(B)]
        done = self.run_until_drained(key=key)
        new = np.stack([done[r] for r in rids])
        return jnp.concatenate([jnp.asarray(prompts_np),
                                jnp.asarray(new)], axis=1)

    def generate_static(self, prompts: jnp.ndarray, n_new: int,
                        temperature: float = 0.0, key=None) -> jnp.ndarray:
        """The seed engine: whole batch prefilled together into dense
        caches, one host-dispatched jitted step per token."""
        B, S0 = prompts.shape
        assert S0 + n_new <= self.max_len
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        out = [prompts]
        last = logits[:, -1]
        key = self._base_key(key)
        for t in range(n_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, last / temperature, axis=-1)
            else:
                nxt = jnp.argmax(last, axis=-1)
            nxt = nxt[:, None].astype(jnp.int32)
            out.append(nxt)
            logits, cache = self._step(self.params, cache, nxt,
                                       jnp.asarray(S0 + t, jnp.int32))
            last = logits[:, 0]
        return jnp.concatenate(out, axis=1)
