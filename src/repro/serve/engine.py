"""Batched serving engine: prefill + step-wise decode over sharded caches.

``make_serve_step`` is the function the decode-shape dry-runs lower:
(params, cache, tokens, pos) -> (logits, cache'), one new token per request
against a seq_len-sized KV/state cache.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import parallel as par
from repro.models import transformer as tfm
from repro.models.layers import Runtime


def make_serve_step(cfg: ModelConfig, rt: Runtime):
    def serve_step(params, cache, tokens, pos):
        logits, cache = tfm.decode_step(cfg, params, cache, tokens, pos, rt)
        return logits, cache
    return serve_step


def make_prefill(cfg: ModelConfig, rt: Runtime, max_len: int):
    def prefill_fn(params, batch):
        return tfm.prefill(cfg, params, batch, rt, max_len)
    return prefill_fn


@dataclasses.dataclass
class ServeEngine:
    """Greedy/temperature batched generation over the public model API."""
    cfg: ModelConfig
    params: Any
    rt: Runtime
    max_len: int
    plan: Optional[par.ParallelPlan] = None

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill(self.cfg, self.rt, self.max_len))
        self._step = jax.jit(make_serve_step(self.cfg, self.rt))

    def generate(self, prompts: jnp.ndarray, n_new: int,
                 temperature: float = 0.0, key=None) -> jnp.ndarray:
        """prompts: (B, S0) int32 -> (B, S0 + n_new)."""
        B, S0 = prompts.shape
        assert S0 + n_new <= self.max_len
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        out = [prompts]
        last = logits[:, -1]
        key = key if key is not None else jax.random.PRNGKey(0)
        for t in range(n_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, last / temperature, axis=-1)
            else:
                nxt = jnp.argmax(last, axis=-1)
            nxt = nxt[:, None].astype(jnp.int32)
            out.append(nxt)
            logits, cache = self._step(self.params, cache, nxt,
                                       jnp.asarray(S0 + t, jnp.int32))
            last = logits[:, 0]
        return jnp.concatenate(out, axis=1)
