from repro.serve.engine import ServeEngine, make_serve_step, make_prefill
from repro.serve.paged_cache import (BlockAllocator, PagedCacheError,
                                     init_paged_cache, init_paged_pools)
from repro.serve.scheduler import Request, Scheduler

__all__ = [
    "ServeEngine", "make_serve_step", "make_prefill",
    "BlockAllocator", "PagedCacheError", "init_paged_cache",
    "init_paged_pools", "Request", "Scheduler",
]
