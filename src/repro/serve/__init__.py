from repro.serve.engine import ServeEngine, make_serve_step, make_prefill

__all__ = ["ServeEngine", "make_serve_step", "make_prefill"]
