"""Paged KV cache: a shared block pool + per-request block tables.

The dense decode cache allocates ``max_len`` KV slots per request up front,
so a 32-token chat and a 32k-token document pay the same HBM.  The paged
cache (vLLM-style) splits KV storage into fixed-size *blocks*:

  * every attention layer owns a pool ``k_pool/v_pool (P, bs, Kv, D)`` —
    P blocks of bs positions each, shared by all in-flight requests;
  * each request holds a *block table* row ``tbl (max_blocks,)`` mapping
    its logical block i to a pool block id (-1 = unallocated) and a
    context length ``ctx`` counting KV entries written so far;
  * the host-side :class:`BlockAllocator` hands out pool block ids with a
    free list and per-block refcounts, so completed requests return their
    blocks and ``fork`` can share a finished prefix between requests.

Pools thread through ``transformer.forward``'s layer scan exactly like the
dense caches (stacked over the scanned blocks); the block table and context
lengths are *shared* read-only state passed alongside (``cache['paged']``)
— layers never mutate them, the engine advances ``ctx`` between steps so
every layer stays in sync by construction.

Absolute position p of request b lives at ``(tbl[b, p // bs], p % bs)``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np


class PagedCacheError(RuntimeError):
    pass


@dataclasses.dataclass
class BlockAllocator:
    """Host-side pool bookkeeping: free list + refcounts.

    Allocation is all-or-nothing (``allocate`` returns None rather than a
    partial grant) so the scheduler can reserve a request's full footprint
    at admission and never OOM mid-flight.  ``fork`` shares fully-written
    blocks by refcount — a shared block must be treated copy-on-write by
    the caller (the engine copies the partial tail block before a forked
    request appends to it).
    """
    n_blocks: int
    block_size: int

    def __post_init__(self):
        self._free: List[int] = list(range(self.n_blocks - 1, -1, -1))
        self._refs = np.zeros(self.n_blocks, dtype=np.int32)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold n_tokens positions."""
        return -(-max(n_tokens, 0) // self.block_size)

    def allocate(self, n: int) -> Optional[List[int]]:
        """Grant n blocks (refcount 1 each) or None if the pool is short."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._refs[out] = 1
        return out

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if self._refs[b] <= 0:
                raise PagedCacheError(f"double free of block {b}")
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(b)

    def fork(self, blocks: List[int]) -> List[int]:
        """Share an existing chain: refcount++ on every block, same ids.

        The forked request reads the shared prefix for free; before it
        *writes* (appends into the last, partially-filled block) the
        caller must replace that block via ``copy_on_write``.
        """
        for b in blocks:
            if self._refs[b] <= 0:
                raise PagedCacheError(f"fork of unallocated block {b}")
            self._refs[b] += 1
        return list(blocks)

    def copy_on_write(self, block: int) -> Optional[int]:
        """Detach one shared block: returns a fresh private block id (the
        caller copies the pool rows device-side), or the same id if the
        block was already private, or None if the pool is exhausted."""
        if self._refs[block] <= 1:
            return block
        fresh = self.allocate(1)
        if fresh is None:
            return None
        self._refs[block] -= 1
        return fresh[0]


def init_paged_pools(cfg, n_blocks: int, block_size: int, dtype,
                     rt=None):
    """Per-layer {k_pool, v_pool} pytree mirroring ``transformer.init_cache``
    (prefix list + stacked scanned blocks) so pools thread through the
    layer scan unchanged.  Every layer must be attention — hybrids keep the
    dense cache path."""
    from repro.models.transformer import _tree_stack, layer_plan

    kv, hd = cfg.kv_heads, cfg.head_dim_
    for i in range(cfg.n_layers):
        if cfg.layer_kind(i) != "attn":
            raise PagedCacheError(
                f"paged cache requires attention-only stacks; layer {i} "
                f"is {cfg.layer_kind(i)!r}")

    def one_layer():
        return {"kv": {
            "k_pool": jnp.zeros((n_blocks, block_size, kv, hd), dtype),
            "v_pool": jnp.zeros((n_blocks, block_size, kv, hd), dtype),
        }}

    prefix, start, period, nb = layer_plan(cfg)
    return {
        "prefix": [one_layer() for _ in prefix],
        "blocks": [_tree_stack([one_layer() for _ in range(nb)])
                   for _ in range(period)] if nb else [],
    }


def init_paged_cache(cfg, n_slots: int, n_blocks: int, block_size: int,
                     max_blocks_per_req: int, dtype, rt=None):
    """Full paged decode cache: pools + shared block-table/ctx state.

    ``tbl (n_slots, max_blocks_per_req)`` int32 (-1 = unallocated);
    ``ctx (n_slots,)`` int32 KV entries written per slot.
    """
    cache = init_paged_pools(cfg, n_blocks, block_size, dtype, rt)
    cache["paged"] = {
        "tbl": jnp.full((n_slots, max_blocks_per_req), -1, jnp.int32),
        "ctx": jnp.zeros((n_slots,), jnp.int32),
    }
    return cache
