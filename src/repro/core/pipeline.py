"""Pipeline parallelism: GPipe schedule over a mesh axis via shard_map +
collective_permute (ppermute), jax-native (no NCCL p2p emulation).

Each device along the ``pipe`` axis owns one *stage* = a contiguous group
of layers (stacked params, leading dim = stage).  A global minibatch is
split into M microbatches; for ``M + P - 1`` ticks every stage computes on
its current activation and ppermutes it to the next stage.  Ticks where a
stage holds no valid microbatch are the *pipeline bubble* — fraction
(P-1)/(M+P-1), exactly the term the paper's cost model charges
(``core/costmodel.py``).

Differentiable: shard_map + ppermute have transpose rules, so the same
function trains under jax.grad (the backward pass runs the reverse
schedule automatically).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):          # jax >= 0.6
    def _shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                   # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _sm

    def _shard_map(f, mesh, in_specs, out_specs):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def pipeline_apply(stage_fn: Callable, params_stacked, x_microbatches,
                   mesh, axis: str = "pipe"):
    """Run x through P stages of stage_fn under a GPipe schedule.

    stage_fn: (stage_params, h) -> h, applied by every stage.
    params_stacked: pytree with leading dim P (one slice per stage).
    x_microbatches: (M, mb, ...) microbatched activations (replicated).
    Returns (M, mb, ...) outputs.
    """
    n_stages = mesh.shape[axis]

    def per_stage(params_local, xs):
        # params_local: stage slice (leading dim 1); xs: (M, mb, ...)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        M = xs.shape[0]
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)          # activation in flight
        outputs = jnp.zeros_like(xs)

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (while valid)
            inject = xs[jnp.minimum(t, M - 1)]
            h = jnp.where(stage == 0, inject, state)
            h = stage_fn(params_local, h)
            # last stage emits microbatch t - (P-1)
            out_slot = t - (n_stages - 1)
            valid = (out_slot >= 0) & (out_slot < M)
            outputs = jax.lax.cond(
                valid & (stage == n_stages - 1),
                lambda o: jax.lax.dynamic_update_slice(
                    o, h[None], (jnp.maximum(out_slot, 0),) + (0,) * h.ndim),
                lambda o: o, outputs)
            # hand activation to the next stage
            state = jax.lax.ppermute(
                h, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (state, outputs), None

        (state, outputs), _ = jax.lax.scan(
            tick, (state, outputs), jnp.arange(M + n_stages - 1))
        # only the last stage's buffer holds real outputs; select+broadcast
        mask = (stage == n_stages - 1).astype(outputs.dtype)
        return jax.lax.psum(outputs * mask, axis)

    pspec = jax.tree.map(lambda _: P(axis), params_stacked)
    fn = _shard_map(per_stage, mesh, in_specs=(pspec, P()), out_specs=P())
    return fn(params_stacked, x_microbatches)


def make_pipelined_block_fn(cfg, rt):
    """stage_fn applying `layers_per_stage` stacked transformer layers."""
    from repro.models.transformer import _apply_layer, _sig

    def stage_fn(stage_params, h):
        # stage_params: {'layers': pytree stacked (L_per_stage, ...)}
        def body(h_, lp):
            h2, _, _ = _apply_layer(cfg, _sig(cfg, 0), lp, h_, None, rt)
            return h2, None
        h, _ = jax.lax.scan(body, h, stage_params["layers"])
        return h

    return stage_fn


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
