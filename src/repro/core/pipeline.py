"""Pipeline parallelism: pluggable schedules (GPipe, 1F1B) over a mesh axis
via shard_map + collective_permute (ppermute), jax-native (no NCCL p2p
emulation).

Each device along the ``pipe`` axis owns one *stage* = a contiguous group
of layers (the stacked layer params are sharded over the pipe axis on
their leading/stack dim, so stage p holds layers [p*L/P, (p+1)*L/P)).  A
minibatch is split into M microbatches that stream through the stages; the
*schedule* decides the per-tick op each stage runs and — crucially — how
many microbatch activations a stage must hold at once:

  * ``gpipe``  — all M forwards first, then (under jax.grad's transposed
    scan) all M backwards.  In-flight activations per stage: M.
  * ``1f1b``   — PipeDream-flush/Megatron one-forward-one-backward: after a
    (P - stage)-deep warmup each stage alternates F and B, so a microbatch's
    stored activation is freed as soon as its backward runs.  In-flight
    activations per stage: min(M, P).

Both schedules idle for the same fraction of ticks — ``(P-1)/(M+P-1)``,
exactly the bubble term ``core/costmodel.step_time`` charges — because at
equal per-tick cost 1F1B *reorders* the bubble rather than removing it.
What 1F1B buys is the smaller activation footprint, which is why the cost
model's ``mem`` term (and therefore ``fits``) is schedule-dependent.

The stage body computes over the *full inner mesh*: activations are
sharded over the batch axes (``x_spec``), stage params over ``axis`` plus
any tensor-/expert-parallel axes named in ``param_specs`` (Megatron-TP
psums and the MoE all-to-all run inside the stage — see
``models/transformer.make_pipelined_block_fn`` / ``core/expert.py``), and
GSPMD all-gathers FSDP-sharded params at entry.

Differentiable: the GPipe path trains through shard_map + ppermute's
transpose rules; the 1F1B path is a ``jax.custom_vjp`` whose backward runs
the combined recompute-forward/backward 1F1B tick loop (the primal stores
only the schedule inputs, so per-stage activation residency really is
bounded by the warmup depth).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

logger = logging.getLogger(__name__)

SCHEDULE_NAMES = ("gpipe", "1f1b")


# ---------------------------------------------------------------------------
# analytic terms (pure python — importable by the cost model without tracing)
# ---------------------------------------------------------------------------

def bubble_fraction(n_stages: int, n_microbatches: int,
                    sched: str = "gpipe") -> float:
    """Idle-tick fraction of the schedule.  Identical for GPipe and 1F1B
    at equal per-tick cost: GPipe idles (P-1) of (M+P-1) ticks in each of
    the forward and backward passes; 1F1B idles 2(P-1) of 2(M+P-1)
    combined ticks.  (1F1B's win is memory, not bubble — see
    ``inflight_microbatches``.)"""
    if sched not in SCHEDULE_NAMES:
        raise ValueError(f"unknown pipeline schedule {sched!r}; "
                         f"expected one of {SCHEDULE_NAMES}")
    if n_stages <= 1:
        return 0.0
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def inflight_microbatches(n_stages: int, n_microbatches: int,
                          sched: str = "gpipe") -> int:
    """Peak number of microbatch activations a stage holds awaiting
    backward — the schedule-dependent factor in pipeline activation
    memory (GPipe: M; 1F1B: min(M, P))."""
    if sched not in SCHEDULE_NAMES:
        raise ValueError(f"unknown pipeline schedule {sched!r}; "
                         f"expected one of {SCHEDULE_NAMES}")
    if n_stages <= 1:
        return n_microbatches
    if sched == "1f1b":
        return min(n_microbatches, n_stages)
    return n_microbatches


# ---------------------------------------------------------------------------
# batch-axis fitting
# ---------------------------------------------------------------------------

_warned_dropped: set = set()


def batch_axes_spec(mesh, axes: Sequence[str], dim_size: int) -> Tuple[str, ...]:
    """The prefix of ``axes`` that divides ``dim_size`` (fit-or-drop).

    Mirrors ``parallel._fit_spec``: when the microbatch row count cannot
    occupy the data axis (e.g. global_batch 8 split into 8 microbatches of
    1 row), the batch dim is kept replicated and the compute is redundant
    across that axis — correct, just not data-parallel.  Dropping an axis
    is logged (once per (axes, size, mesh-shape) combination) because the
    redundancy is silent in every other signal: the step *runs*, only
    ``dp``-fold slower per token than the plan's mesh suggests.
    """
    keep = []
    size = dim_size
    for a in axes:
        n = mesh.shape[a]
        if n > 1 and size % n == 0 and size >= n:
            keep.append(a)
            size //= n
    dropped = tuple(a for a in axes if a not in keep and mesh.shape[a] > 1)
    if dropped:
        key = (tuple(axes), dim_size,
               tuple((a, int(mesh.shape[a])) for a in axes))
        if key not in _warned_dropped:
            _warned_dropped.add(key)
            logger.warning(
                "pipeline microbatch of %d rows does not occupy batch "
                "mesh axes %s (sizes %s): the microbatch is replicated "
                "and compute is redundant across them — use a larger "
                "global batch or fewer microbatches for true data "
                "parallelism", dim_size, dropped,
                tuple(int(mesh.shape[a]) for a in dropped))
    return tuple(keep)


def _entry(axes: Tuple[str, ...]):
    return axes if len(axes) > 1 else (axes[0] if axes else None)


# ---------------------------------------------------------------------------
# the shared forward tick loop (used by GPipe's differentiable path and as
# the 1F1B primal)
# ---------------------------------------------------------------------------

def _make_fwd_body(stage_fn: Callable, axis: str, n_stages: int):
    def per_stage(params_local, xs, extras_local):
        # params_local: (L/P, ...) stage slice; xs: (M, local_mb, ...)
        stage = jax.lax.axis_index(axis)
        M = xs.shape[0]
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)          # activation in flight
        # its running aux loss — carried as shape (1,), never a scalar:
        # scalar shard_map residuals break the jax<=0.4 transpose (they
        # cannot take the residuals' dim-0 sharding)
        aux_state = jnp.zeros((1,), jnp.float32)
        outputs = jnp.zeros_like(xs)
        aux_out = jnp.zeros((M,), jnp.float32)

        def tick(carry, t):
            state, aux_state, outputs, aux_out = carry
            # stage 0 ingests microbatch t (while valid)
            inject = xs[jnp.minimum(t, M - 1)]
            h = jnp.where(stage == 0, inject, state)
            a = jnp.where(stage == 0, 0.0, aux_state)
            h, a_stage = stage_fn(params_local, h, extras_local)
            a = a + a_stage.astype(jnp.float32).reshape((1,))
            # last stage emits microbatch t - (P-1)
            out_slot = t - (n_stages - 1)
            valid = (out_slot >= 0) & (out_slot < M)
            emit = valid & (stage == n_stages - 1)
            outputs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_slice(
                    o, h[None], (jnp.maximum(out_slot, 0),) + (0,) * h.ndim),
                lambda o: o, outputs)
            aux_out = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_slice(
                    o, a, (jnp.maximum(out_slot, 0),)),
                lambda o: o, aux_out)
            # hand activation (+ its aux so far) to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = jax.lax.ppermute(h, axis, perm)
            aux_state = jax.lax.ppermute(a, axis, perm)
            return (state, aux_state, outputs, aux_out), None

        (state, aux_state, outputs, aux_out), _ = jax.lax.scan(
            tick, (state, aux_state, outputs, aux_out),
            jnp.arange(M + n_stages - 1))
        # only the last stage's buffer holds real outputs; select+broadcast.
        # aux leaves as the (M,) per-microbatch vector, reduced outside the
        # shard_map — a scalar output that doubles as a backward residual
        # trips jax<=0.4's transpose (scalars cannot take the residuals'
        # dim-0 sharding)
        mask = (stage == n_stages - 1).astype(outputs.dtype)
        outputs = jax.lax.psum(outputs * mask, axis)
        aux_mb = jax.lax.psum(
            aux_out * (stage == n_stages - 1).astype(jnp.float32), axis)
        return outputs, aux_mb

    return per_stage


@dataclasses.dataclass(frozen=True)
class _Specs:
    """Resolved shard_map specs for one pipeline_apply call."""
    x_spec: P
    pspec: object                      # pytree of P over stage_params
    espec: object                      # pytree of P over extras
    kept: Tuple[str, ...]              # batch axes actually sharding the mb
    seq_axis: str                      # context axis sharding the seq dim


def _resolve_specs(stage_params, x, mesh, axis, extras, batch_axes,
                   param_specs, seq_axis) -> _Specs:
    kept = batch_axes_spec(mesh, batch_axes, x.shape[1])
    entries: List = [None, _entry(kept)]
    if seq_axis:
        if x.ndim < 3 or x.shape[2] % mesh.shape[seq_axis]:
            raise ValueError(
                f"context-parallel pipeline needs the sequence dim "
                f"(x.shape={x.shape}) divisible by mesh axis "
                f"{seq_axis!r}={mesh.shape[seq_axis]}")
        entries.append(seq_axis)
    x_spec = P(*entries)
    pspec = (jax.tree.map(lambda _: P(axis), stage_params)
             if param_specs is None else param_specs)
    espec = jax.tree.map(lambda _: P(), extras)
    return _Specs(x_spec, pspec, espec, kept, seq_axis)


def _token_axes(specs: _Specs) -> Tuple[str, ...]:
    """Mesh axes over which the stage body's tokens are sharded (the axes
    whose param-cotangent contributions are distinct and must be summed)."""
    return specs.kept + ((specs.seq_axis,) if specs.seq_axis else ())


def _spec_axes(spec: P) -> Tuple[str, ...]:
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return tuple(out)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

class PipelineSchedule:
    """One pipeline execution schedule: per-tick op tables (for simulation
    and tests), analytic bubble/memory terms, and the executable
    ``apply`` that runs stage_fn over the mesh."""

    name: str = "?"

    # ---- analytic -------------------------------------------------------
    def bubble_fraction(self, n_stages: int, n_microbatches: int) -> float:
        return bubble_fraction(n_stages, n_microbatches, self.name)

    def inflight_microbatches(self, n_stages: int,
                              n_microbatches: int) -> int:
        return inflight_microbatches(n_stages, n_microbatches, self.name)

    # ---- simulation -----------------------------------------------------
    def tick_table(self, n_stages: int, n_microbatches: int
                   ) -> List[List[Tuple[str, int]]]:
        """[tick][stage] -> ('F', j) | ('B', j) | ('idle', -1) covering the
        full fwd+bwd execution.  Host-side python; the executable loops are
        index arithmetic over exactly these tables."""
        raise NotImplementedError

    def simulate(self, n_stages: int, n_microbatches: int) -> Dict:
        """Counted-from-the-table bubble fraction and peak in-flight
        activations — what the analytic formulas must reproduce."""
        table = self.tick_table(n_stages, n_microbatches)
        idle = sum(op == "idle" for row in table for op, _ in row)
        total = len(table) * n_stages
        peak = 0
        inflight = [set() for _ in range(n_stages)]
        for row in table:
            for s, (op, j) in enumerate(row):
                if op == "F":
                    inflight[s].add(j)
                elif op == "B":
                    inflight[s].discard(j)
            peak = max(peak, max(len(f) for f in inflight))
        return {"ticks": len(table), "bubble": idle / total,
                "peak_inflight": peak}

    # ---- execution ------------------------------------------------------
    def apply(self, stage_fn, stage_params, x, mesh, axis, extras,
              batch_axes=(), param_specs=None, seq_axis="", tp_axis=""):
        raise NotImplementedError


class GPipeSchedule(PipelineSchedule):
    """All forwards, then (under autodiff's transposed scan) all
    backwards; M microbatch activations in flight per stage."""

    name = "gpipe"

    def tick_table(self, n_stages, n_microbatches):
        P_, M = n_stages, n_microbatches
        table = []
        for t in range(M + P_ - 1):                       # forward pass
            table.append([("F", t - s) if 0 <= t - s < M else ("idle", -1)
                          for s in range(P_)])
        for u in range(M + P_ - 1):                       # transposed scan
            t = M + P_ - 2 - u
            table.append([("B", t - s) if 0 <= t - s < M else ("idle", -1)
                          for s in range(P_)])
        return table

    def apply(self, stage_fn, stage_params, x, mesh, axis, extras,
              batch_axes=(), param_specs=None, seq_axis="", tp_axis=""):
        n_stages = mesh.shape[axis]
        specs = _resolve_specs(stage_params, x, mesh, axis, extras,
                               batch_axes, param_specs, seq_axis)
        fn = _shard_map(_make_fwd_body(stage_fn, axis, n_stages), mesh,
                        in_specs=(specs.pspec, specs.x_spec, specs.espec),
                        out_specs=(specs.x_spec, P()))
        return fn(stage_params, x, extras)


class OneFOneBSchedule(PipelineSchedule):
    """1F1B (PipeDream-flush): stage s runs P - s warmup forwards, then
    alternates one-forward-one-backward, then drains.  Per-stage in-flight
    activations <= P instead of M.

    Executable via ``jax.custom_vjp``: the primal runs the plain forward
    tick loop storing only the schedule *inputs*; the backward replays
    microbatch forwards just-in-time through the pipe (standard remat,
    like the GPipe path under ``Runtime.remat``) interleaved with the
    per-microbatch backwards in 1F1B order, holding at most min(M, P)
    stage-input activations in a ring buffer.

    Tick alignment: stage s forwards microbatch j at tick ``s + j`` during
    warmup (j < P - s) and ``2j + s`` in steady state; it backwards j at
    ``2j + 2P - 1 - s`` — so every consumed value was produced by the
    neighbor exactly one tick earlier, except across the warmup/steady
    boundary, where receivers *latch* the incoming value until their
    scheduled tick (neighbors forward idle-tick payloads are ignored).
    """

    name = "1f1b"

    # -- tick arithmetic (shared by the table and the traced loop) --------
    @staticmethod
    def _fwd_tick(P_, M, s, j):
        return s + j if j < P_ - s else 2 * j + s

    @staticmethod
    def _bwd_tick(P_, M, s, j):
        return 2 * j + 2 * P_ - 1 - s

    def tick_table(self, n_stages, n_microbatches):
        P_, M = n_stages, n_microbatches
        if M < P_:
            raise ValueError(f"1f1b needs microbatches >= stages "
                             f"(got M={M} < P={P_})")
        total = 2 * (M + P_ - 1)
        table = [[("idle", -1)] * P_ for _ in range(total)]
        for s in range(P_):
            for j in range(M):
                table[self._fwd_tick(P_, M, s, j)][s] = ("F", j)
                table[self._bwd_tick(P_, M, s, j)][s] = ("B", j)
        return table

    def apply(self, stage_fn, stage_params, x, mesh, axis, extras,
              batch_axes=(), param_specs=None, seq_axis="", tp_axis=""):
        n_stages = mesh.shape[axis]
        M = x.shape[0]
        if M < n_stages:
            raise ValueError(f"1f1b needs microbatches >= stages "
                             f"(got M={M} < P={n_stages})")
        W = min(M, n_stages)            # activation ring depth
        specs = _resolve_specs(stage_params, x, mesh, axis, extras,
                               batch_axes, param_specs, seq_axis)
        fwd_sm = _shard_map(
            _make_fwd_body(stage_fn, axis, n_stages), mesh,
            in_specs=(specs.pspec, specs.x_spec, specs.espec),
            out_specs=(specs.x_spec, P()))

        tok_axes = _token_axes(specs)
        # Megatron-TP cotangent convention inside the manual loop: the
        # stage body contains raw psums, so a replicated value's physical
        # cotangents must SUM across model ranks to the logical one (the
        # "split" convention — see layers.tp_reduce_out).  Injected
        # cotangents (dy, d_aux) are therefore divided by tp, and the
        # final reductions psum back over the model axis.
        tp_div = mesh.shape[tp_axis] if tp_axis else 1
        grad_axes = tok_axes + (
            (tp_axis,) if tp_axis and tp_axis not in tok_axes else ())
        # per-leaf gradient reduction: sum over the axes this leaf is
        # replicated across but whose contributions are distinct (token
        # shards; split model-cotangents under TP).  A leaf already
        # sharded over 'expert'/'model' owns its slice's cotangent.
        p_reduce = jax.tree.map(
            lambda sp: tuple(a for a in grad_axes
                             if a not in _spec_axes(sp)),
            specs.pspec, is_leaf=lambda s: isinstance(s, P))
        # extras feed every stage and every token/head shard
        e_reduce = (axis,) + grad_axes

        def bwd_body(params_local, xs, extras_local, dy, d_aux):
            stage = jax.lax.axis_index(axis)
            Mi = xs.shape[0]
            mb_shape = xs.shape[1:]
            total = 2 * (Mi + n_stages - 1)
            fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            bwd_perm = [(i, (i - 1) % n_stages) for i in range(n_stages)]
            zeros_mb = jnp.zeros(mb_shape, xs.dtype)

            def is_f_at(s, t):
                warm_s = n_stages - s
                jw = t - s
                is_warm = (jw >= 0) & (jw < warm_s)
                js = jw // 2
                steady = (jw >= 0) & (jw % 2 == 0) & (js >= warm_s) & (js < Mi)
                return is_warm | steady, jnp.clip(
                    jnp.where(is_warm, jw, js), 0, Mi - 1)

            def is_b_at(s, t):
                tb = t - (2 * n_stages - 1 - s)
                return (tb >= 0) & (tb % 2 == 0) & (tb // 2 < Mi), \
                    jnp.clip(tb // 2, 0, Mi - 1)

            def tick(carry, t):
                h_pend, cot_pend, act_buf, d_params, d_extras, d_xs = carry
                is_f, jf = is_f_at(stage, t)
                is_b, jb = is_b_at(stage, t)

                def b_branch(op):
                    h_pend, act_buf, d_params, d_extras, d_xs = op
                    h_saved = jax.lax.dynamic_index_in_dim(
                        act_buf, jb % W, axis=0, keepdims=False)
                    dy_in = jnp.where(stage == n_stages - 1,
                                      dy[jb] / tp_div, cot_pend)
                    da = d_aux[jb].astype(jnp.float32) / tp_div
                    _, vjp_fn = jax.vjp(stage_fn, params_local, h_saved,
                                        extras_local)
                    dp, dh, de = vjp_fn((dy_in, da.reshape(())))
                    d_params = jax.tree.map(jnp.add, d_params, dp)
                    d_extras = jax.tree.map(jnp.add, d_extras, de)
                    upd = jax.lax.dynamic_update_slice(
                        d_xs, dh[None].astype(d_xs.dtype),
                        (jb,) + (0,) * dh.ndim)
                    d_xs = jnp.where(stage == 0, upd, d_xs)
                    return zeros_mb, dh, act_buf, d_params, d_extras, d_xs

                def f_branch(op):
                    h_pend, act_buf, d_params, d_extras, d_xs = op

                    def do_f(opb):
                        h_pend, act_buf = opb
                        x_in = jnp.where(stage == 0, xs[jf], h_pend)
                        h_out, _ = stage_fn(params_local, x_in, extras_local)
                        act_buf = jax.lax.dynamic_update_slice(
                            act_buf, x_in[None],
                            (jf % W,) + (0,) * x_in.ndim)
                        return h_out, act_buf

                    h_out, act_buf = jax.lax.cond(
                        is_f, do_f, lambda opb: (zeros_mb, opb[1]),
                        (h_pend, act_buf))
                    return h_out, zeros_mb, act_buf, d_params, d_extras, d_xs

                out = jax.lax.cond(
                    is_b, b_branch, f_branch,
                    (h_pend, act_buf, d_params, d_extras, d_xs))
                h_pay, cot_pay, act_buf, d_params, d_extras, d_xs = out
                h_recv = jax.lax.ppermute(h_pay, axis, fwd_perm)
                cot_recv = jax.lax.ppermute(cot_pay, axis, bwd_perm)
                # latch: accept only freshly-produced neighbor values (idle
                # ticks send zeros, and across the warmup/steady boundary a
                # value is consumed several ticks after it was produced)
                prev_f, _ = is_f_at((stage - 1) % n_stages, t)
                next_b, _ = is_b_at((stage + 1) % n_stages, t)
                h_pend = jnp.where(prev_f, h_recv, h_pend)
                cot_pend = jnp.where(next_b, cot_recv, cot_pend)
                return (h_pend, cot_pend, act_buf,
                        d_params, d_extras, d_xs), None

            carry0 = (zeros_mb, zeros_mb,
                      jnp.zeros((W,) + mb_shape, xs.dtype),
                      jax.tree.map(jnp.zeros_like, params_local),
                      jax.tree.map(jnp.zeros_like, extras_local),
                      jnp.zeros_like(xs))
            (_, _, _, d_params, d_extras, d_xs), _ = jax.lax.scan(
                tick, carry0, jnp.arange(total))
            d_params = jax.tree.map(
                lambda g, axes: jax.lax.psum(g, axes) if axes else g,
                d_params, p_reduce)
            d_extras = jax.tree.map(
                lambda g: jax.lax.psum(g, e_reduce), d_extras)
            # only stage 0 wrote d_xs; under TP its per-model-rank values
            # are split cotangents — the psum also recombines those
            d_xs = jax.lax.psum(
                d_xs, (axis,) + ((tp_axis,) if tp_axis else ()))
            return d_params, d_xs, d_extras

        bwd_sm = _shard_map(
            bwd_body, mesh,
            in_specs=(specs.pspec, specs.x_spec, specs.espec,
                      specs.x_spec, P()),
            out_specs=(specs.pspec, specs.x_spec, specs.espec))

        @jax.custom_vjp
        def call(stage_params, x, extras):
            return fwd_sm(stage_params, x, extras)

        def call_fwd(stage_params, x, extras):
            # residuals are the schedule *inputs* only — the backward
            # regenerates stage activations just-in-time (<= P in flight)
            return fwd_sm(stage_params, x, extras), (stage_params, x, extras)

        def call_bwd(res, cots):
            stage_params, x, extras = res
            d_out, d_aux = cots
            return bwd_sm(stage_params, x, extras, d_out, d_aux)

        call.defvjp(call_fwd, call_bwd)
        return call(stage_params, x, extras)


SCHEDULES: Dict[str, PipelineSchedule] = {
    "gpipe": GPipeSchedule(),
    "1f1b": OneFOneBSchedule(),
}


def get_schedule(name: str) -> PipelineSchedule:
    try:
        return SCHEDULES[name]
    except KeyError:
        raise ValueError(f"unknown pipeline schedule {name!r}; "
                         f"expected one of {sorted(SCHEDULES)}") from None


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def pipeline_apply(stage_fn: Callable, stage_params, x_microbatches,
                   mesh, axis: str = "pipe", extras=None,
                   batch_axes: Sequence[str] = (), schedule: str = "gpipe",
                   param_specs=None, seq_axis: str = "", tp_axis: str = ""):
    """Run x through P stages of stage_fn under the named schedule.

    stage_fn: (stage_params_local, h, extras) -> (h, aux), applied by every
      stage on its local slice of the stacked layer params; ``aux`` is a
      float32 scalar per-stage extra loss (the MoE load-balance term) that
      rides along the activation through the schedule.  It must be
      *shard-invariant* across the batch/model axes (the MoE stats are
      psum-reduced inside the router for exactly this reason).
    stage_params: pytree whose leaves have a leading stack dim divisible by
      the pipe axis size (sharded contiguously over ``axis``: stage p gets
      slice [p*L/P, (p+1)*L/P)).
    x_microbatches: (M, mb, ...) microbatched activations; the mb (batch)
      dim is sharded over ``batch_axes`` when divisible, else replicated.
    extras: pytree broadcast to every stage unsharded (e.g. rope angles
      with batch dim 1).
    schedule: 'gpipe' | '1f1b' (see module docstring).
    param_specs: optional pytree of PartitionSpecs for stage_params; the
      default shards only the stack dim over ``axis``.  Inner-mesh plans
      pass Megatron-TP / expert-sharded specs so the stage body computes
      over the model/expert axes instead of replicating.
    seq_axis: mesh axis sharding the sequence dim of x inside the stage
      (manual context parallelism; the stage body must gather KV).
    tp_axis: mesh axis the stage body runs Megatron psums over (used to
      reduce extras-cotangents; the psums themselves live in stage_fn).

    Returns ((M, mb, ...) outputs sharded like x, aux summed over
    microbatches and stages — a replicated scalar).
    """
    out, aux_mb = get_schedule(schedule).apply(
        stage_fn, stage_params, x_microbatches, mesh, axis, extras,
        batch_axes=batch_axes, param_specs=param_specs, seq_axis=seq_axis,
        tp_axis=tp_axis)
    return out, aux_mb.sum()


def make_pipelined_block_fn(cfg, rt):
    """stage_fn applying this stage's slice of the stacked layer params.

    ``extras`` carries the rope angles (batch dim 1, broadcast over the
    local microbatch).  The Runtime must have ``constrain=None``: the
    stage body runs inside a fully-manual shard_map where named-sharding
    constraints are meaningless.  Inner-mesh composition is driven by the
    Runtime fields:

      * ``rt.tp_reduce_axis``  — Megatron-TP: the layer code sees a
        head/hidden-local config (the caller shards params over the model
        axis via ``param_specs``) and ``_apply_layer`` psums the mixer/ffn
        outputs over this axis;
      * ``rt.cp_axis``         — manual context parallelism: attention
        gathers KV over this axis and offsets its causal mask;
      * ``rt.moe_impl == 'ep_manual'`` — MoE layers dispatch through
        ``core/expert.py``'s all-to-all on ``rt.expert_axis`` directly
        (we are already inside the manual mesh).

    Returns (h, aux): the per-stage sum of the MoE load-balance losses of
    this stage's layers (zeros for dense stacks), which the schedule
    threads through the ticks.
    """
    from repro.models.transformer import _apply_layer, _sig

    sig = _sig(cfg, 0)
    cfg_stage = cfg
    if rt.tp_reduce_axis:
        # Megatron-TP inside the manual mesh: the stage body sees *local*
        # head/hidden shapes, so hand the layer code a config with local
        # counts (head_dim pinned first — it must not be re-derived from
        # the sliced head count)
        tp = rt.pipeline_mesh.shape[rt.tp_reduce_axis]
        cfg_stage = dataclasses.replace(
            cfg, head_dim=cfg.head_dim_,
            n_heads=cfg.n_heads // tp,
            n_kv_heads=cfg.kv_heads // tp)

    apply = _apply_layer
    if rt.remat:
        apply = jax.checkpoint(_apply_layer, static_argnums=(0, 1, 5))

    def stage_fn(stage_params, h, rope_ang):
        # stage_params: {'layers': pytree stacked (L_per_stage, ...)}
        def body(carry, lp):
            h_, aux_ = carry
            h2, _, a = apply(cfg_stage, sig, lp, h_, rope_ang, rt)
            return (h2, aux_ + a), None
        (h, aux), _ = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)), stage_params["layers"])
        return h, aux

    return stage_fn


def measure_bubble_fraction(step_for_m: Callable[[int], Callable[[], object]],
                            n_stages: int, microbatches: int,
                            m2: Optional[int] = None,
                            n_iter: int = 3, sched: str = "gpipe") -> dict:
    """Empirically estimate the pipeline bubble from wall time.

    ``step_for_m(M)`` returns a zero-arg compiled callable running the
    pipelined step with M microbatches at *fixed microbatch size* (total
    batch grows with M), so t(M) = t_tick * (M + P - 1) + overhead is
    linear in M.  A two-point fit recovers t_tick, and

        bubble_measured = (P - 1) * t_tick / t(M)

    which equals (P-1)/(M+P-1) up to the constant overhead term — the
    executable counterpart of ``bubble_fraction`` / the cost model's
    per-schedule bubble charge.

    On a noisy host the two-point fit can come out non-increasing
    (t(2M) <= t(M)); that is *not* a zero bubble, it is a failed fit —
    the record flags it as ``fit_unreliable`` so downstream consumers
    (dryrun artifacts, BENCH_pipeline.json, the tier-1 probe test) can
    retry or discard instead of trusting a fabricated 0.0.
    """
    m1 = microbatches
    m2 = m2 or 2 * m1

    def timed(fn):
        fn()                                   # compile / warm up
        best = float("inf")
        for _ in range(n_iter):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    t1 = timed(step_for_m(m1))
    t2 = timed(step_for_m(m2))
    unreliable = t2 <= t1 or t1 <= 0
    t_tick = max((t2 - t1) / (m2 - m1), 0.0)
    measured = (n_stages - 1) * t_tick / t1 if t1 > 0 else 0.0
    return {
        "pp": n_stages, "microbatches": m1, "sched": sched,
        "t_step_s": t1, "t_step_2m_s": t2, "t_tick_s": t_tick,
        "bubble_predicted": bubble_fraction(n_stages, m1, sched),
        "bubble_measured": measured,
        "fit_unreliable": bool(unreliable),
    }
