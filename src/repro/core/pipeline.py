"""Pipeline parallelism: pluggable schedules (GPipe, 1F1B, interleaved
1F1B, zero-bubble) over a mesh axis via shard_map + collective_permute
(ppermute), jax-native (no NCCL p2p emulation).

Each device along the ``pipe`` axis owns one *stage* = a contiguous group
of layers (the stacked layer params are sharded over the pipe axis on
their leading/stack dim, so stage p holds layers [p*L/P, (p+1)*L/P)).  A
minibatch is split into M microbatches that stream through the stages; the
*schedule* decides the per-tick op each stage runs and — crucially — how
many microbatch activations a stage must hold at once:

  * ``gpipe``  — all M forwards first, then (under jax.grad's transposed
    scan) all M backwards.  In-flight activations per stage: M.
  * ``1f1b``   — PipeDream-flush/Megatron one-forward-one-backward: after a
    (P - stage)-deep warmup each stage alternates F and B, so a microbatch's
    stored activation is freed as soon as its backward runs.  In-flight
    activations per stage: min(M, P).
  * ``1f1b_i<v>`` — Megatron *interleaved* 1F1B: each rank holds ``v``
    non-contiguous chunks of the layer stack (virtual stage ``c*P + r``
    on rank r), so every microbatch crosses the ring v times but each
    warmup/drain idle amortizes over vM chunk ticks — bubble
    (P-1)/(vM+P-1) at v× the p2p volume and a deeper warmup window of
    (1/v-sized) chunk activations.
  * ``zb``     — zero-bubble 1F1B (ZB-H1 family): each backward splits
    into a dgrad sub-tick (activation cotangent, frees the stored input)
    and a deferred wgrad sub-tick that fills the drain — bubble
    2(P-1)/(3M+2P-2) < (P-1)/(M+P-1) at 1f1b's activation footprint plus
    a small parameter-gradient stash.

gpipe and 1f1b idle for the same fraction of ticks — ``(P-1)/(M+P-1)``,
exactly the bubble term ``core/costmodel.step_time`` charges — because at
equal per-tick cost 1F1B *reorders* the bubble rather than removing it
(what it buys is the smaller activation footprint, which is why the cost
model's ``mem`` term and therefore ``fits`` is schedule-dependent).  The
interleaved and zero-bubble schedules genuinely shrink the bubble, paying
in p2p volume / warmup depth (interleaved) or sub-tick count and wgrad
stash (zb) — the frontier ``costmodel.step_time`` charges per schedule.

The stage body computes over the *full inner mesh*: activations are
sharded over the batch axes (``x_spec``), stage params over ``axis`` plus
any tensor-/expert-parallel axes named in ``param_specs`` (Megatron-TP
psums and the MoE all-to-all run inside the stage — see
``models/transformer.make_pipelined_block_fn`` / ``core/expert.py``), and
GSPMD all-gathers FSDP-sharded params at entry.

Differentiable: the GPipe path trains through shard_map + ppermute's
transpose rules; the 1F1B path is a ``jax.custom_vjp`` whose backward runs
the combined recompute-forward/backward 1F1B tick loop (the primal stores
only the schedule inputs, so per-stage activation residency really is
bounded by the warmup depth).
"""
from __future__ import annotations

import dataclasses
import logging
import re
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

logger = logging.getLogger(__name__)

# base schedule families; interleaved schedules are the parametric family
# '1f1b_i<v>' (v >= 2 virtual stages per rank) on top of these
SCHEDULE_NAMES = ("gpipe", "1f1b", "zb")

_INTERLEAVED_RE = re.compile(r"^1f1b_i(\d+)$")


def parse_schedule(sched: str) -> Tuple[str, int]:
    """Split a schedule name into (family, virtual_stages).

    'gpipe' / '1f1b' / 'zb' -> (name, 1); '1f1b_i<v>' -> ('1f1b_i', v)
    with v >= 2 (v == 1 is plain 1f1b — rejected to keep names canonical).
    Raises ValueError for anything else, so every validation site shares
    one grammar."""
    m = _INTERLEAVED_RE.match(sched)
    if m:
        v = int(m.group(1))
        if v < 2:
            raise ValueError(
                f"interleaved schedule {sched!r} needs v >= 2 virtual "
                "stages per rank (v == 1 is plain '1f1b')")
        return "1f1b_i", v
    if sched in SCHEDULE_NAMES:
        return sched, 1
    raise ValueError(f"unknown pipeline schedule {sched!r}; expected one "
                     f"of {SCHEDULE_NAMES} or '1f1b_i<v>' (v >= 2)")


def known_schedule(sched: str) -> bool:
    try:
        parse_schedule(sched)
        return True
    except ValueError:
        return False


def virtual_stages(sched: str) -> int:
    """Virtual stages (param chunks) per pipe rank: v for '1f1b_i<v>',
    1 for every flat schedule."""
    return parse_schedule(sched)[1]


# ---------------------------------------------------------------------------
# analytic terms (pure python — importable by the cost model without tracing)
# ---------------------------------------------------------------------------

def bubble_fraction(n_stages: int, n_microbatches: int,
                    sched: str = "gpipe") -> float:
    """Idle-tick fraction of the schedule.

      * gpipe / 1f1b — (P-1)/(M+P-1): identical at equal per-tick cost
        (1F1B *reorders* the bubble to cap in-flight activations, it does
        not shrink it);
      * 1f1b_i<v>  — (P-1)/(vM+P-1): v virtual stages per rank slice each
        tick v ways, so the same warmup/drain idles amortize over vM work
        ticks (Megatron interleaved);
      * zb         — 2(P-1)/(3M+2P-2): each backward splits into dgrad and
        wgrad sub-ticks (F/B/W all one sub-tick) and the deferred wgrads
        fill the drain; only the 2(P-1) warmup+drain sub-ticks idle, out
        of 3M work sub-ticks per rank (ZB-H1 with a bounded wgrad
        backlog).  Strictly below 1f1b's bubble for every M >= 1.
    """
    family, v = parse_schedule(sched)
    if n_stages <= 1:
        return 0.0
    P_, M = n_stages, n_microbatches
    if family == "1f1b_i":
        return (P_ - 1) / (v * M + P_ - 1)
    if family == "zb":
        return 2 * (P_ - 1) / (3 * M + 2 * P_ - 2)
    return (P_ - 1) / (M + P_ - 1)


def inflight_microbatches(n_stages: int, n_microbatches: int,
                          sched: str = "gpipe") -> int:
    """Peak number of in-flight activations a rank holds awaiting
    backward — the schedule-dependent factor in pipeline activation
    memory.

      * gpipe      — M whole-stage activations;
      * 1f1b / zb  — min(M, P) whole-stage activations (zb's dgrad
        sub-tick frees the activation exactly where 1f1b's combined
        backward does; the deferred wgrad keeps only a param-shaped
        gradient stash, charged separately by the cost model);
      * 1f1b_i<v>  — min(2(P-1) + (v-1)P + 1, vM) *chunk* activations,
        each covering 1/v of the rank's layer slice (the rank-0 warmup
        depth of the interleaved schedule) — divide by v before comparing
        against whole-stage units.
    """
    family, v = parse_schedule(sched)
    P_, M = n_stages, n_microbatches
    if n_stages <= 1:
        return M
    if family == "1f1b_i":
        return min(2 * (P_ - 1) + (v - 1) * P_ + 1, v * M)
    if family in ("1f1b", "zb"):
        return min(M, P_)
    return M


# ---------------------------------------------------------------------------
# batch-axis fitting
# ---------------------------------------------------------------------------

_warned_dropped: set = set()


def batch_axes_spec(mesh, axes: Sequence[str], dim_size: int) -> Tuple[str, ...]:
    """The prefix of ``axes`` that divides ``dim_size`` (fit-or-drop).

    Mirrors ``parallel._fit_spec``: when the microbatch row count cannot
    occupy the data axis (e.g. global_batch 8 split into 8 microbatches of
    1 row), the batch dim is kept replicated and the compute is redundant
    across that axis — correct, just not data-parallel.  Dropping an axis
    is logged (once per (axes, size, mesh-shape) combination) because the
    redundancy is silent in every other signal: the step *runs*, only
    ``dp``-fold slower per token than the plan's mesh suggests.
    """
    keep = []
    size = dim_size
    for a in axes:
        n = mesh.shape[a]
        if n > 1 and size % n == 0 and size >= n:
            keep.append(a)
            size //= n
    dropped = tuple(a for a in axes if a not in keep and mesh.shape[a] > 1)
    if dropped:
        key = (tuple(axes), dim_size,
               tuple((a, int(mesh.shape[a])) for a in axes))
        if key not in _warned_dropped:
            _warned_dropped.add(key)
            logger.warning(
                "pipeline microbatch of %d rows does not occupy batch "
                "mesh axes %s (sizes %s): the microbatch is replicated "
                "and compute is redundant across them — use a larger "
                "global batch or fewer microbatches for true data "
                "parallelism", dim_size, dropped,
                tuple(int(mesh.shape[a]) for a in dropped))
    return tuple(keep)


def _entry(axes: Tuple[str, ...]):
    return axes if len(axes) > 1 else (axes[0] if axes else None)


# ---------------------------------------------------------------------------
# the shared forward tick loop (used by GPipe's differentiable path and as
# the 1F1B primal)
# ---------------------------------------------------------------------------

def _make_fwd_body(stage_fn: Callable, axis: str, n_stages: int):
    def per_stage(params_local, xs, extras_local):
        # params_local: (L/P, ...) stage slice; xs: (M, local_mb, ...)
        stage = jax.lax.axis_index(axis)
        M = xs.shape[0]
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)          # activation in flight
        # its running aux loss — carried as shape (1,), never a scalar:
        # scalar shard_map residuals break the jax<=0.4 transpose (they
        # cannot take the residuals' dim-0 sharding)
        aux_state = jnp.zeros((1,), jnp.float32)
        outputs = jnp.zeros_like(xs)
        aux_out = jnp.zeros((M,), jnp.float32)

        def tick(carry, t):
            state, aux_state, outputs, aux_out = carry
            # stage 0 ingests microbatch t (while valid)
            inject = xs[jnp.minimum(t, M - 1)]
            h = jnp.where(stage == 0, inject, state)
            a = jnp.where(stage == 0, 0.0, aux_state)
            h, a_stage = stage_fn(params_local, h, extras_local)
            a = a + a_stage.astype(jnp.float32).reshape((1,))
            # last stage emits microbatch t - (P-1)
            out_slot = t - (n_stages - 1)
            valid = (out_slot >= 0) & (out_slot < M)
            emit = valid & (stage == n_stages - 1)
            outputs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_slice(
                    o, h[None], (jnp.maximum(out_slot, 0),) + (0,) * h.ndim),
                lambda o: o, outputs)
            aux_out = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_slice(
                    o, a, (jnp.maximum(out_slot, 0),)),
                lambda o: o, aux_out)
            # hand activation (+ its aux so far) to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = jax.lax.ppermute(h, axis, perm)
            aux_state = jax.lax.ppermute(a, axis, perm)
            return (state, aux_state, outputs, aux_out), None

        (state, aux_state, outputs, aux_out), _ = jax.lax.scan(
            tick, (state, aux_state, outputs, aux_out),
            jnp.arange(M + n_stages - 1))
        # only the last stage's buffer holds real outputs; select+broadcast.
        # aux leaves as the (M,) per-microbatch vector, reduced outside the
        # shard_map — a scalar output that doubles as a backward residual
        # trips jax<=0.4's transpose (scalars cannot take the residuals'
        # dim-0 sharding)
        mask = (stage == n_stages - 1).astype(outputs.dtype)
        outputs = jax.lax.psum(outputs * mask, axis)
        aux_mb = jax.lax.psum(
            aux_out * (stage == n_stages - 1).astype(jnp.float32), axis)
        return outputs, aux_mb

    return per_stage


@dataclasses.dataclass(frozen=True)
class _Specs:
    """Resolved shard_map specs for one pipeline_apply call."""
    x_spec: P
    pspec: object                      # pytree of P over stage_params
    espec: object                      # pytree of P over extras
    kept: Tuple[str, ...]              # batch axes actually sharding the mb
    seq_axis: str                      # context axis sharding the seq dim


def _resolve_specs(stage_params, x, mesh, axis, extras, batch_axes,
                   param_specs, seq_axis) -> _Specs:
    kept = batch_axes_spec(mesh, batch_axes, x.shape[1])
    entries: List = [None, _entry(kept)]
    if seq_axis:
        if x.ndim < 3 or x.shape[2] % mesh.shape[seq_axis]:
            raise ValueError(
                f"context-parallel pipeline needs the sequence dim "
                f"(x.shape={x.shape}) divisible by mesh axis "
                f"{seq_axis!r}={mesh.shape[seq_axis]}")
        entries.append(seq_axis)
    x_spec = P(*entries)
    pspec = (jax.tree.map(lambda _: P(axis), stage_params)
             if param_specs is None else param_specs)
    espec = jax.tree.map(lambda _: P(), extras)
    return _Specs(x_spec, pspec, espec, kept, seq_axis)


def _token_axes(specs: _Specs) -> Tuple[str, ...]:
    """Mesh axes over which the stage body's tokens are sharded (the axes
    whose param-cotangent contributions are distinct and must be summed)."""
    return specs.kept + ((specs.seq_axis,) if specs.seq_axis else ())


def _spec_axes(spec: P) -> Tuple[str, ...]:
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return tuple(out)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

class PipelineSchedule:
    """One pipeline execution schedule: per-tick op tables (for simulation
    and tests), analytic bubble/memory terms, and the executable
    ``apply`` that runs stage_fn over the mesh."""

    name: str = "?"

    # ---- analytic -------------------------------------------------------
    def bubble_fraction(self, n_stages: int, n_microbatches: int) -> float:
        return bubble_fraction(n_stages, n_microbatches, self.name)

    def inflight_microbatches(self, n_stages: int,
                              n_microbatches: int) -> int:
        return inflight_microbatches(n_stages, n_microbatches, self.name)

    # ---- simulation -----------------------------------------------------
    def tick_table(self, n_stages: int, n_microbatches: int
                   ) -> List[List[Tuple[str, int]]]:
        """[tick][stage] -> ('F', j) | ('B', j) | ('idle', -1) covering the
        full fwd+bwd execution.  Host-side python; the executable loops are
        index arithmetic over exactly these tables."""
        raise NotImplementedError

    def simulate(self, n_stages: int, n_microbatches: int) -> Dict:
        """Counted-from-the-table bubble fraction and peak in-flight
        activations — what the analytic formulas must reproduce."""
        table = self.tick_table(n_stages, n_microbatches)
        idle = sum(op == "idle" for row in table for op, _ in row)
        total = len(table) * n_stages
        peak = 0
        inflight = [set() for _ in range(n_stages)]
        for row in table:
            for s, (op, j) in enumerate(row):
                if op == "F":
                    inflight[s].add(j)
                elif op == "B":
                    inflight[s].discard(j)
            peak = max(peak, max(len(f) for f in inflight))
        return {"ticks": len(table), "bubble": idle / total,
                "peak_inflight": peak}

    # ---- execution ------------------------------------------------------
    def apply(self, stage_fn, stage_params, x, mesh, axis, extras,
              batch_axes=(), param_specs=None, seq_axis="", tp_axis=""):
        raise NotImplementedError


class GPipeSchedule(PipelineSchedule):
    """All forwards, then (under autodiff's transposed scan) all
    backwards; M microbatch activations in flight per stage."""

    name = "gpipe"

    def tick_table(self, n_stages, n_microbatches):
        P_, M = n_stages, n_microbatches
        table = []
        for t in range(M + P_ - 1):                       # forward pass
            table.append([("F", t - s) if 0 <= t - s < M else ("idle", -1)
                          for s in range(P_)])
        for u in range(M + P_ - 1):                       # transposed scan
            t = M + P_ - 2 - u
            table.append([("B", t - s) if 0 <= t - s < M else ("idle", -1)
                          for s in range(P_)])
        return table

    def apply(self, stage_fn, stage_params, x, mesh, axis, extras,
              batch_axes=(), param_specs=None, seq_axis="", tp_axis=""):
        n_stages = mesh.shape[axis]
        specs = _resolve_specs(stage_params, x, mesh, axis, extras,
                               batch_axes, param_specs, seq_axis)
        fn = _shard_map(_make_fwd_body(stage_fn, axis, n_stages), mesh,
                        in_specs=(specs.pspec, specs.x_spec, specs.espec),
                        out_specs=(specs.x_spec, P()))
        return fn(stage_params, x, extras)


class OneFOneBSchedule(PipelineSchedule):
    """1F1B (PipeDream-flush): stage s runs P - s warmup forwards, then
    alternates one-forward-one-backward, then drains.  Per-stage in-flight
    activations <= P instead of M.

    Executable via ``jax.custom_vjp``: the primal runs the plain forward
    tick loop storing only the schedule *inputs*; the backward replays
    microbatch forwards just-in-time through the pipe (standard remat,
    like the GPipe path under ``Runtime.remat``) interleaved with the
    per-microbatch backwards in 1F1B order, holding at most min(M, P)
    stage-input activations in a ring buffer.

    Tick alignment: stage s forwards microbatch j at tick ``s + j`` during
    warmup (j < P - s) and ``2j + s`` in steady state; it backwards j at
    ``2j + 2P - 1 - s`` — so every consumed value was produced by the
    neighbor exactly one tick earlier, except across the warmup/steady
    boundary, where receivers *latch* the incoming value until their
    scheduled tick (neighbors forward idle-tick payloads are ignored).
    """

    name = "1f1b"

    # -- tick arithmetic (shared by the table and the traced loop) --------
    @staticmethod
    def _fwd_tick(P_, M, s, j):
        return s + j if j < P_ - s else 2 * j + s

    @staticmethod
    def _bwd_tick(P_, M, s, j):
        return 2 * j + 2 * P_ - 1 - s

    def tick_table(self, n_stages, n_microbatches):
        P_, M = n_stages, n_microbatches
        if M < P_:
            raise ValueError(f"1f1b needs microbatches >= stages "
                             f"(got M={M} < P={P_})")
        total = 2 * (M + P_ - 1)
        table = [[("idle", -1)] * P_ for _ in range(total)]
        for s in range(P_):
            for j in range(M):
                table[self._fwd_tick(P_, M, s, j)][s] = ("F", j)
                table[self._bwd_tick(P_, M, s, j)][s] = ("B", j)
        return table

    def apply(self, stage_fn, stage_params, x, mesh, axis, extras,
              batch_axes=(), param_specs=None, seq_axis="", tp_axis=""):
        n_stages = mesh.shape[axis]
        M = x.shape[0]
        if M < n_stages:
            raise ValueError(f"1f1b needs microbatches >= stages "
                             f"(got M={M} < P={n_stages})")
        W = min(M, n_stages)            # activation ring depth
        specs = _resolve_specs(stage_params, x, mesh, axis, extras,
                               batch_axes, param_specs, seq_axis)
        fwd_sm = _shard_map(
            _make_fwd_body(stage_fn, axis, n_stages), mesh,
            in_specs=(specs.pspec, specs.x_spec, specs.espec),
            out_specs=(specs.x_spec, P()))

        tok_axes = _token_axes(specs)
        # Megatron-TP cotangent convention inside the manual loop: the
        # stage body contains raw psums, so a replicated value's physical
        # cotangents must SUM across model ranks to the logical one (the
        # "split" convention — see layers.tp_reduce_out).  Injected
        # cotangents (dy, d_aux) are therefore divided by tp, and the
        # final reductions psum back over the model axis.
        tp_div = mesh.shape[tp_axis] if tp_axis else 1
        grad_axes = tok_axes + (
            (tp_axis,) if tp_axis and tp_axis not in tok_axes else ())
        # per-leaf gradient reduction: sum over the axes this leaf is
        # replicated across but whose contributions are distinct (token
        # shards; split model-cotangents under TP).  A leaf already
        # sharded over 'expert'/'model' owns its slice's cotangent.
        p_reduce = jax.tree.map(
            lambda sp: tuple(a for a in grad_axes
                             if a not in _spec_axes(sp)),
            specs.pspec, is_leaf=lambda s: isinstance(s, P))
        # extras feed every stage and every token/head shard
        e_reduce = (axis,) + grad_axes

        def bwd_body(params_local, xs, extras_local, dy, d_aux):
            stage = jax.lax.axis_index(axis)
            Mi = xs.shape[0]
            mb_shape = xs.shape[1:]
            total = 2 * (Mi + n_stages - 1)
            fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            bwd_perm = [(i, (i - 1) % n_stages) for i in range(n_stages)]
            zeros_mb = jnp.zeros(mb_shape, xs.dtype)

            def is_f_at(s, t):
                warm_s = n_stages - s
                jw = t - s
                is_warm = (jw >= 0) & (jw < warm_s)
                js = jw // 2
                steady = (jw >= 0) & (jw % 2 == 0) & (js >= warm_s) & (js < Mi)
                return is_warm | steady, jnp.clip(
                    jnp.where(is_warm, jw, js), 0, Mi - 1)

            def is_b_at(s, t):
                tb = t - (2 * n_stages - 1 - s)
                return (tb >= 0) & (tb % 2 == 0) & (tb // 2 < Mi), \
                    jnp.clip(tb // 2, 0, Mi - 1)

            def tick(carry, t):
                h_pend, cot_pend, act_buf, d_params, d_extras, d_xs = carry
                is_f, jf = is_f_at(stage, t)
                is_b, jb = is_b_at(stage, t)

                def b_branch(op):
                    h_pend, act_buf, d_params, d_extras, d_xs = op
                    h_saved = jax.lax.dynamic_index_in_dim(
                        act_buf, jb % W, axis=0, keepdims=False)
                    dy_in = jnp.where(stage == n_stages - 1,
                                      dy[jb] / tp_div, cot_pend)
                    da = d_aux[jb].astype(jnp.float32) / tp_div
                    _, vjp_fn = jax.vjp(stage_fn, params_local, h_saved,
                                        extras_local)
                    dp, dh, de = vjp_fn((dy_in, da.reshape(())))
                    d_params = jax.tree.map(jnp.add, d_params, dp)
                    d_extras = jax.tree.map(jnp.add, d_extras, de)
                    upd = jax.lax.dynamic_update_slice(
                        d_xs, dh[None].astype(d_xs.dtype),
                        (jb,) + (0,) * dh.ndim)
                    d_xs = jnp.where(stage == 0, upd, d_xs)
                    return zeros_mb, dh, act_buf, d_params, d_extras, d_xs

                def f_branch(op):
                    h_pend, act_buf, d_params, d_extras, d_xs = op

                    def do_f(opb):
                        h_pend, act_buf = opb
                        x_in = jnp.where(stage == 0, xs[jf], h_pend)
                        h_out, _ = stage_fn(params_local, x_in, extras_local)
                        act_buf = jax.lax.dynamic_update_slice(
                            act_buf, x_in[None],
                            (jf % W,) + (0,) * x_in.ndim)
                        return h_out, act_buf

                    h_out, act_buf = jax.lax.cond(
                        is_f, do_f, lambda opb: (zeros_mb, opb[1]),
                        (h_pend, act_buf))
                    return h_out, zeros_mb, act_buf, d_params, d_extras, d_xs

                out = jax.lax.cond(
                    is_b, b_branch, f_branch,
                    (h_pend, act_buf, d_params, d_extras, d_xs))
                h_pay, cot_pay, act_buf, d_params, d_extras, d_xs = out
                h_recv = jax.lax.ppermute(h_pay, axis, fwd_perm)
                cot_recv = jax.lax.ppermute(cot_pay, axis, bwd_perm)
                # latch: accept only freshly-produced neighbor values (idle
                # ticks send zeros, and across the warmup/steady boundary a
                # value is consumed several ticks after it was produced)
                prev_f, _ = is_f_at((stage - 1) % n_stages, t)
                next_b, _ = is_b_at((stage + 1) % n_stages, t)
                h_pend = jnp.where(prev_f, h_recv, h_pend)
                cot_pend = jnp.where(next_b, cot_recv, cot_pend)
                return (h_pend, cot_pend, act_buf,
                        d_params, d_extras, d_xs), None

            carry0 = (zeros_mb, zeros_mb,
                      jnp.zeros((W,) + mb_shape, xs.dtype),
                      jax.tree.map(jnp.zeros_like, params_local),
                      jax.tree.map(jnp.zeros_like, extras_local),
                      jnp.zeros_like(xs))
            (_, _, _, d_params, d_extras, d_xs), _ = jax.lax.scan(
                tick, carry0, jnp.arange(total))
            d_params = jax.tree.map(
                lambda g, axes: jax.lax.psum(g, axes) if axes else g,
                d_params, p_reduce)
            d_extras = jax.tree.map(
                lambda g: jax.lax.psum(g, e_reduce), d_extras)
            # only stage 0 wrote d_xs; under TP its per-model-rank values
            # are split cotangents — the psum also recombines those
            d_xs = jax.lax.psum(
                d_xs, (axis,) + ((tp_axis,) if tp_axis else ()))
            return d_params, d_xs, d_extras

        bwd_sm = _shard_map(
            bwd_body, mesh,
            in_specs=(specs.pspec, specs.x_spec, specs.espec,
                      specs.x_spec, P()),
            out_specs=(specs.pspec, specs.x_spec, specs.espec))

        @jax.custom_vjp
        def call(stage_params, x, extras):
            return fwd_sm(stage_params, x, extras)

        def call_fwd(stage_params, x, extras):
            # residuals are the schedule *inputs* only — the backward
            # regenerates stage activations just-in-time (<= P in flight)
            return fwd_sm(stage_params, x, extras), (stage_params, x, extras)

        def call_bwd(res, cots):
            stage_params, x, extras = res
            d_out, d_aux = cots
            return bwd_sm(stage_params, x, extras, d_out, d_aux)

        call.defvjp(call_fwd, call_bwd)
        return call(stage_params, x, extras)


# ---------------------------------------------------------------------------
# table-driven schedules: interleaved 1F1B and zero-bubble
# ---------------------------------------------------------------------------
# The closed-form tick arithmetic of OneFOneBSchedule does not extend to
# interleaved virtual stages (per-rank op order depends on warmup depth AND
# chunk rotation) or to zero-bubble's three sub-tick kinds, so these
# schedules build an explicit host-side (tick, rank) -> (op, chunk, mb)
# table with a greedy list scheduler and drive both the primal forward
# scan and the custom_vjp combined recompute/backward scan from static
# int32 arrays derived from that table.

_OP_CODES = {"idle": 0, "F": 1, "B": 2, "W": 3}


def _interleaved_full_table(P_, M, v):
    """Greedy Megatron-order interleaved 1F1B.

    Virtual stage ``sv = c*P + r`` (chunk c of rank r); per-rank op order
    is the Megatron one — forwards in groups of P microbatches,
    chunk-major within the group; backwards the same with chunks
    reversed — after a ``min(2(P-1-r) + (v-1)P + 1, vM)`` warmup.  The
    result achieves exactly T = 2(vM+P-1) ticks and bubble
    (P-1)/(vM+P-1) with peak in-flight chunk activations equal to the
    rank-0 warmup depth."""
    if M % P_:
        raise ValueError(
            f"interleaved 1f1b needs microbatches divisible by stages "
            f"(got M={M}, P={P_}: the chunk rotation assigns microbatches "
            "to ranks in groups of P)")
    S = v * P_
    order_f = [(c, g * P_ + o) for g in range(M // P_)
               for c in range(v) for o in range(P_)]
    order_b = [(c, g * P_ + o) for g in range(M // P_)
               for c in range(v - 1, -1, -1) for o in range(P_)]
    warm = [min(2 * (P_ - r - 1) + (v - 1) * P_ + 1, v * M)
            for r in range(P_)]
    done_f, done_b = {}, {}
    fi = [0] * P_
    bi = [0] * P_
    table = []
    t = 0
    while any(fi[r] < v * M or bi[r] < v * M for r in range(P_)):
        row = []
        for r in range(P_):
            entry = ("idle", 0, 0)
            if fi[r] < warm[r] and bi[r] == 0:
                want = "F"                      # warmup forwards
            elif bi[r] < v * M and (fi[r] >= v * M
                                    or bi[r] <= fi[r] - warm[r]):
                want = "B"                      # steady 1B after warmup
            elif fi[r] < v * M:
                want = "F"
            else:
                want = "B"
            for cand in (want, "B" if want == "F" else "F"):
                if cand == "F" and fi[r] < v * M:
                    c, j = order_f[fi[r]]
                    sv = c * P_ + r
                    if sv == 0 or done_f.get((sv - 1, j), t) < t:
                        entry = ("F", c, j)
                        done_f[(sv, j)] = t
                        fi[r] += 1
                        break
                elif cand == "B" and bi[r] < v * M:
                    c, j = order_b[bi[r]]
                    sv = c * P_ + r
                    ok = (done_b.get((sv + 1, j), t) < t if sv < S - 1
                          else done_f.get((sv, j), t) < t)
                    if ok:
                        entry = ("B", c, j)
                        done_b[(sv, j)] = t
                        bi[r] += 1
                        break
            row.append(entry)
        table.append(row)
        t += 1
        if t > 6 * (v * M + P_):
            raise RuntimeError("interleaved schedule made no progress")
    return table


def _zb_full_table(P_, M):
    """Greedy zero-bubble (ZB-H1-style) table: each backward splits into a
    dgrad sub-tick ('B': activation cotangent, frees the stored input) and
    a deferred wgrad sub-tick ('W': parameter gradient) that fills what
    would otherwise be drain idle time.

    Priority B > W > F keeps the wgrad backlog at <= 1 pending microbatch
    per rank while still reaching T = 3M + 2(P-1) sub-ticks — bubble
    2(P-1)/(3M+2P-2), strictly below 1f1b's (P-1)/(M+P-1) for all M.
    (B > F > W reaches the (P-1)/(3M+P-1) floor but lets the backlog grow
    to M — an O(M) param-gradient stash for a second-order win.)"""
    if M < P_:
        raise ValueError(f"zb needs microbatches >= stages "
                         f"(got M={M} < P={P_})")
    done_f, done_b = {}, {}
    fi = [0] * P_
    bi = [0] * P_
    wi = [0] * P_
    table = []
    t = 0
    while any(fi[r] < M or bi[r] < M or wi[r] < M for r in range(P_)):
        row = []
        for r in range(P_):
            entry = ("idle", 0, 0)
            if bi[r] < M and (done_b.get((r + 1, bi[r]), t) < t
                              if r < P_ - 1
                              else done_f.get((r, bi[r]), t) < t):
                entry = ("B", 0, bi[r])
                done_b[(r, bi[r])] = t
                bi[r] += 1
            elif wi[r] < bi[r]:
                entry = ("W", 0, wi[r])
                wi[r] += 1
            elif fi[r] < M and fi[r] - bi[r] < P_ - r and \
                    (r == 0 or done_f.get((r - 1, fi[r]), t) < t):
                entry = ("F", 0, fi[r])
                done_f[(r, fi[r])] = t
                fi[r] += 1
            row.append(entry)
        table.append(row)
        t += 1
        if t > 6 * (3 * M + 2 * P_):
            raise RuntimeError("zb schedule made no progress")
    return table


def _fwd_only_table(P_, M, v):
    """Forward-only table (the custom_vjp primal): each rank runs its
    Megatron-order forwards as soon as the upstream virtual stage has
    produced the input."""
    S = v * P_
    order_f = [(c, g * P_ + o) for g in range(M // P_)
               for c in range(v) for o in range(P_)] if v > 1 else \
        [(0, j) for j in range(M)]
    done_f = {}
    fi = [0] * P_
    table = []
    t = 0
    while any(fi[r] < v * M for r in range(P_)):
        row = []
        for r in range(P_):
            entry = ("idle", 0, 0)
            if fi[r] < v * M:
                c, j = order_f[fi[r]]
                sv = c * P_ + r
                if sv == 0 or done_f.get((sv - 1, j), t) < t:
                    entry = ("F", c, j)
                    done_f[(sv, j)] = t
                    fi[r] += 1
            row.append(entry)
        table.append(row)
        t += 1
        if t > 6 * (v * M + P_):
            raise RuntimeError("forward table made no progress")
    return table


def _max_overlap(intervals):
    """Peak count of integer-time intervals [a, b] simultaneously alive."""
    events = []
    for a, b in intervals:
        if b >= a:
            events.append((a, 1))
            events.append((b + 1, -1))
    events.sort()
    cur = peak = 0
    for _, d in events:
        cur += d
        peak = max(peak, cur)
    return peak


def _ring_depths(table, P_, M, v):
    """Ring-buffer depths the executable needs for this exact table:
    (act, pend_f, pend_b, wgrad-stash) — the per-(rank, chunk) peak count
    of stored stage inputs (F..B), inbound activations (upstream F..own
    F), inbound cotangents (downstream B..own B) and pending wgrads
    (B..W).  Production/consumption are both j-ascending per chunk, so
    the alive set is a consecutive microbatch window and slot ``j % depth``
    is collision-free."""
    S = v * P_
    tf, tb, tw = {}, {}, {}
    for t, row in enumerate(table):
        for r, (op, c, j) in enumerate(row):
            sv = c * P_ + r
            if op == "F":
                tf[(sv, j)] = t
            elif op == "B":
                tb[(sv, j)] = t
            elif op == "W":
                tw[(sv, j)] = t
    da = df = db = dw = 1
    for sv in range(S):
        if tb:
            da = max(da, _max_overlap(
                [(tf[(sv, j)], tb[(sv, j)] - 1) for j in range(M)]))
        if sv > 0:
            df = max(df, _max_overlap(
                [(tf[(sv - 1, j)], tf[(sv, j)] - 1) for j in range(M)]))
        if tb and sv < S - 1:
            db = max(db, _max_overlap(
                [(tb[(sv + 1, j)], tb[(sv, j)] - 1) for j in range(M)]))
        if tw:
            dw = max(dw, _max_overlap(
                [(tb[(sv, j)], tw[(sv, j)] - 1) for j in range(M)]))
    return da, df, db, dw


def _sched_arrays(table, P_, M, v, df, db):
    """Static int32 (T, P) arrays driving the traced tick loop: per-tick
    op/chunk/microbatch for this rank, virtual-stage-boundary flags, and
    where (if anywhere) to store the values arriving over the two
    ppermute rings this tick (derived from what the *neighbors* ran)."""
    S = v * P_
    T = len(table)

    def zeros():
        return np.zeros((T, P_), np.int32)

    a = {k: zeros() for k in ("op", "c", "j", "sv0", "svl", "sf_on", "sf_c",
                              "sf_slot", "sb_on", "sb_c", "sb_slot")}
    for t, row in enumerate(table):
        for r, (op, c, j) in enumerate(row):
            a["op"][t, r] = _OP_CODES[op]
            if op == "idle":
                continue
            a["c"][t, r] = c
            a["j"][t, r] = j
            sv = c * P_ + r
            a["sv0"][t, r] = int(sv == 0)
            a["svl"][t, r] = int(sv == S - 1)
        for r in range(P_):
            lop, lc, lj = row[(r - 1) % P_]           # fwd ring: left -> r
            if lop == "F":
                sv = lc * P_ + (r - 1) % P_
                if sv < S - 1:
                    a["sf_on"][t, r] = 1
                    a["sf_c"][t, r] = (sv + 1) // P_
                    a["sf_slot"][t, r] = lj % df
            rop, rc, rj = row[(r + 1) % P_]           # bwd ring: right -> r
            if rop == "B":
                sv = rc * P_ + (r + 1) % P_
                if sv > 0:
                    a["sb_on"][t, r] = 1
                    a["sb_c"][t, r] = (sv - 1) // P_
                    a["sb_slot"][t, r] = rj % db
    return {k: jnp.asarray(val) for k, val in a.items()}


class _TableSchedule(PipelineSchedule):
    """Shared executor for the table-driven schedules (interleaved 1F1B,
    zero-bubble).  Subclasses provide the full fwd+bwd table; execution
    follows the 1F1B custom_vjp pattern — the primal stores only the
    schedule inputs, the backward replays microbatch forwards
    just-in-time — generalized to per-chunk ring buffers, a chunked view
    of the rank's layer slice, and (zb) a deferred parameter-gradient
    stash written at the dgrad sub-tick and drained at the wgrad one."""

    v: int = 1
    has_wgrad: bool = False

    def _full_table(self, n_stages, n_microbatches):
        raise NotImplementedError

    def tick_table(self, n_stages, n_microbatches):
        # (op, chunk*M + mb): unique work-item ids so ``simulate`` counts
        # chunk activations (F adds, B frees — W keeps only a param-shaped
        # stash, not an activation)
        M = n_microbatches
        return [[(op, c * M + j) if op != "idle" else ("idle", -1)
                 for (op, c, j) in row]
                for row in self._full_table(n_stages, n_microbatches)]

    # -- execution --------------------------------------------------------
    def apply(self, stage_fn, stage_params, x, mesh, axis, extras,
              batch_axes=(), param_specs=None, seq_axis="", tp_axis=""):
        n_stages = mesh.shape[axis]
        M = x.shape[0]
        v = self.v
        full_table = self._full_table(n_stages, M)     # validates M vs P
        fwd_table = _fwd_only_table(n_stages, M, v)
        da, df, db, dw = _ring_depths(full_table, n_stages, M, v)
        _, f_df, _, _ = _ring_depths(fwd_table, n_stages, M, v)

        leaves = jax.tree.leaves(stage_params)
        L = leaves[0].shape[0] if leaves else 0
        if L % (n_stages * v):
            raise ValueError(
                f"{L} stacked layers do not split into pipe={n_stages} x "
                f"v={v} virtual-stage chunks ({self.name})")
        if v > 1:
            # re-chunk the stack: rank r's contiguous pipe shard must hold
            # the v non-contiguous slices of virtual stages c*P + r.
            # jnp.take is differentiable and sits outside the custom_vjp,
            # so its transpose un-permutes the param cotangents for free.
            nl = L // (n_stages * v)
            perm = np.array([(c * n_stages + r) * nl + i
                             for r in range(n_stages)
                             for c in range(v)
                             for i in range(nl)], dtype=np.int32)
            stage_params = jax.tree.map(
                lambda a: jnp.take(a, perm, axis=0), stage_params)
        specs = _resolve_specs(stage_params, x, mesh, axis, extras,
                               batch_axes, param_specs, seq_axis)

        def chunked(tree):
            return jax.tree.map(
                lambda a: a.reshape((v, a.shape[0] // v) + a.shape[1:]),
                tree)

        def pick(tree, idx):
            return jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, idx, 0, keepdims=False), tree)

        def ring_read(buf, c, slot):
            return jax.lax.dynamic_index_in_dim(
                jax.lax.dynamic_index_in_dim(buf, c, 0, keepdims=False),
                slot, 0, keepdims=False)

        def ring_write(buf, val, c, slot):
            return jax.lax.dynamic_update_slice(
                buf, val[None][None], (c, slot) + (0,) * val.ndim)

        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        bwd_perm = [(i, (i - 1) % n_stages) for i in range(n_stages)]

        def fwd_body(params_local, xs, extras_local):
            stage = jax.lax.axis_index(axis)
            mb_shape = xs.shape[1:]
            pv = chunked(params_local)
            arrs = _sched_arrays(fwd_table, n_stages, M, v, f_df, 1)

            def tick(carry, tarr):
                pend_h, pend_a, outputs, aux_out = carry
                op = tarr["op"][stage]
                c = tarr["c"][stage]
                j = tarr["j"][stage]
                first = tarr["sv0"][stage].astype(bool)
                last = tarr["svl"][stage].astype(bool)
                slot = jnp.mod(j, f_df)
                h_in = jnp.where(first, xs[j], ring_read(pend_h, c, slot))
                a_in = jnp.where(first, jnp.zeros((1,), jnp.float32),
                                 ring_read(pend_a, c, slot))
                h_out, a_stage = stage_fn(pick(pv, c), h_in, extras_local)
                a_out = a_in + a_stage.astype(jnp.float32).reshape((1,))
                emit = (op == 1) & last
                outputs = jax.lax.cond(
                    emit,
                    lambda o: jax.lax.dynamic_update_slice(
                        o, h_out[None], (j,) + (0,) * h_out.ndim),
                    lambda o: o, outputs)
                aux_out = jax.lax.cond(
                    emit,
                    lambda o: jax.lax.dynamic_update_slice(o, a_out, (j,)),
                    lambda o: o, aux_out)
                h_recv = jax.lax.ppermute(h_out, axis, fwd_perm)
                a_recv = jax.lax.ppermute(a_out, axis, fwd_perm)
                on = tarr["sf_on"][stage].astype(bool)
                dc = tarr["sf_c"][stage]
                ds = tarr["sf_slot"][stage]
                pend_h = jax.lax.cond(
                    on, lambda b: ring_write(b, h_recv, dc, ds),
                    lambda b: b, pend_h)
                pend_a = jax.lax.cond(
                    on, lambda b: ring_write(b, a_recv, dc, ds),
                    lambda b: b, pend_a)
                return (pend_h, pend_a, outputs, aux_out), None

            carry0 = (jnp.zeros((v, f_df) + mb_shape, xs.dtype),
                      jnp.zeros((v, f_df, 1), jnp.float32),
                      jnp.zeros_like(xs), jnp.zeros((M,), jnp.float32))
            (_, _, outputs, aux_out), _ = jax.lax.scan(tick, carry0, arrs)
            mask = (stage == n_stages - 1)
            outputs = jax.lax.psum(
                outputs * mask.astype(outputs.dtype), axis)
            aux_mb = jax.lax.psum(
                aux_out * mask.astype(jnp.float32), axis)
            return outputs, aux_mb

        tok_axes = _token_axes(specs)
        # split-cotangent Megatron-TP convention — see OneFOneBSchedule
        tp_div = mesh.shape[tp_axis] if tp_axis else 1
        grad_axes = tok_axes + (
            (tp_axis,) if tp_axis and tp_axis not in tok_axes else ())
        p_reduce = jax.tree.map(
            lambda sp: tuple(a for a in grad_axes
                             if a not in _spec_axes(sp)),
            specs.pspec, is_leaf=lambda s: isinstance(s, P))
        e_reduce = (axis,) + grad_axes

        def bwd_body(params_local, xs, extras_local, dy, d_aux):
            stage = jax.lax.axis_index(axis)
            mb_shape = xs.shape[1:]
            pv = chunked(params_local)
            arrs = _sched_arrays(full_table, n_stages, M, v, df, db)
            zeros_mb = jnp.zeros(mb_shape, xs.dtype)

            def tick(carry, tarr):
                (pend_h, pend_c, act_buf, d_params, d_extras, d_xs,
                 dp_stash) = carry
                c = tarr["c"][stage]
                j = tarr["j"][stage]
                first = tarr["sv0"][stage].astype(bool)
                last = tarr["svl"][stage].astype(bool)
                cp = pick(pv, c)

                def idle_br(op_in):
                    (pend_h, pend_c, act_buf, d_params, d_extras, d_xs,
                     dp_stash) = op_in
                    return (zeros_mb, zeros_mb, act_buf, d_params,
                            d_extras, d_xs, dp_stash)

                def f_br(op_in):
                    (pend_h, pend_c, act_buf, d_params, d_extras, d_xs,
                     dp_stash) = op_in
                    x_in = jnp.where(first, xs[j],
                                     ring_read(pend_h, c, jnp.mod(j, df)))
                    h_out, _ = stage_fn(cp, x_in, extras_local)
                    act_buf = ring_write(act_buf, x_in, c, jnp.mod(j, da))
                    return (h_out, zeros_mb, act_buf, d_params, d_extras,
                            d_xs, dp_stash)

                def b_br(op_in):
                    (pend_h, pend_c, act_buf, d_params, d_extras, d_xs,
                     dp_stash) = op_in
                    h_saved = ring_read(act_buf, c, jnp.mod(j, da))
                    dy_in = jnp.where(last, dy[j] / tp_div,
                                      ring_read(pend_c, c, jnp.mod(j, db)))
                    da_cot = d_aux[j].astype(jnp.float32) / tp_div
                    _, vjp_fn = jax.vjp(stage_fn, cp, h_saved, extras_local)
                    dpc, dh, de = vjp_fn((dy_in, da_cot.reshape(())))
                    if self.has_wgrad:
                        # dgrad sub-tick: defer the param gradient to the
                        # W sub-tick; only the (depth-dw) stash survives
                        dp_stash = jax.tree.map(
                            lambda s, g: jax.lax.dynamic_update_slice(
                                s, g[None],
                                (jnp.mod(j, dw),) + (0,) * g.ndim),
                            dp_stash, dpc)
                    else:
                        d_params = jax.tree.map(
                            lambda A, g: jax.lax.dynamic_update_slice(
                                A, (jax.lax.dynamic_index_in_dim(
                                    A, c, 0, keepdims=False) + g)[None],
                                (c,) + (0,) * g.ndim),
                            d_params, dpc)
                    d_extras = jax.tree.map(jnp.add, d_extras, de)
                    upd = jax.lax.dynamic_update_slice(
                        d_xs, dh[None].astype(d_xs.dtype),
                        (j,) + (0,) * dh.ndim)
                    d_xs = jnp.where(first, upd, d_xs)
                    return (zeros_mb, dh, act_buf, d_params, d_extras,
                            d_xs, dp_stash)

                def w_br(op_in):
                    (pend_h, pend_c, act_buf, d_params, d_extras, d_xs,
                     dp_stash) = op_in
                    g = pick(dp_stash, jnp.mod(j, dw))
                    d_params = jax.tree.map(
                        lambda A, gg: jax.lax.dynamic_update_slice(
                            A, (jax.lax.dynamic_index_in_dim(
                                A, c, 0, keepdims=False) + gg)[None],
                            (c,) + (0,) * gg.ndim),
                        d_params, g)
                    return (zeros_mb, zeros_mb, act_buf, d_params,
                            d_extras, d_xs, dp_stash)

                branches = [idle_br, f_br, b_br]
                if self.has_wgrad:
                    branches.append(w_br)
                out = jax.lax.switch(
                    tarr["op"][stage], branches,
                    (pend_h, pend_c, act_buf, d_params, d_extras, d_xs,
                     dp_stash))
                (f_pay, b_pay, act_buf, d_params, d_extras, d_xs,
                 dp_stash) = out
                h_recv = jax.lax.ppermute(f_pay, axis, fwd_perm)
                c_recv = jax.lax.ppermute(b_pay, axis, bwd_perm)
                pend_h = jax.lax.cond(
                    tarr["sf_on"][stage].astype(bool),
                    lambda b: ring_write(b, h_recv, tarr["sf_c"][stage],
                                         tarr["sf_slot"][stage]),
                    lambda b: b, pend_h)
                pend_c = jax.lax.cond(
                    tarr["sb_on"][stage].astype(bool),
                    lambda b: ring_write(b, c_recv, tarr["sb_c"][stage],
                                         tarr["sb_slot"][stage]),
                    lambda b: b, pend_c)
                return (pend_h, pend_c, act_buf, d_params, d_extras,
                        d_xs, dp_stash), None

            dp_stash0 = (jax.tree.map(
                lambda a: jnp.zeros((dw,) + a.shape[1:], a.dtype), pv)
                if self.has_wgrad else None)
            carry0 = (jnp.zeros((v, df) + mb_shape, xs.dtype),
                      jnp.zeros((v, db) + mb_shape, xs.dtype),
                      jnp.zeros((v, da) + mb_shape, xs.dtype),
                      jax.tree.map(jnp.zeros_like, pv),
                      jax.tree.map(jnp.zeros_like, extras_local),
                      jnp.zeros_like(xs),
                      dp_stash0)
            (_, _, _, d_params, d_extras, d_xs, _), _ = jax.lax.scan(
                tick, carry0, arrs)
            d_params = jax.tree.map(
                lambda A, a: A.reshape(a.shape), d_params, params_local)
            d_params = jax.tree.map(
                lambda g, axes: jax.lax.psum(g, axes) if axes else g,
                d_params, p_reduce)
            d_extras = jax.tree.map(
                lambda g: jax.lax.psum(g, e_reduce), d_extras)
            d_xs = jax.lax.psum(
                d_xs, (axis,) + ((tp_axis,) if tp_axis else ()))
            return d_params, d_xs, d_extras

        fwd_sm = _shard_map(
            fwd_body, mesh,
            in_specs=(specs.pspec, specs.x_spec, specs.espec),
            out_specs=(specs.x_spec, P()))
        bwd_sm = _shard_map(
            bwd_body, mesh,
            in_specs=(specs.pspec, specs.x_spec, specs.espec,
                      specs.x_spec, P()),
            out_specs=(specs.pspec, specs.x_spec, specs.espec))

        @jax.custom_vjp
        def call(stage_params, x, extras):
            return fwd_sm(stage_params, x, extras)

        def call_fwd(stage_params, x, extras):
            return fwd_sm(stage_params, x, extras), (stage_params, x, extras)

        def call_bwd(res, cots):
            stage_params, x, extras = res
            d_out, d_aux = cots
            return bwd_sm(stage_params, x, extras, d_out, d_aux)

        call.defvjp(call_fwd, call_bwd)
        return call(stage_params, x, extras)


class InterleavedOneFOneBSchedule(_TableSchedule):
    """Interleaved 1F1B (Megatron virtual stages): each pipe rank holds
    ``v`` non-contiguous chunks of the layer stack (virtual stage
    ``c*P + r`` on rank r), so warmup/drain idles amortize over vM chunk
    ticks — bubble (P-1)/(vM+P-1) — at the price of each microbatch
    crossing the p2p ring v times and a deeper warmup window of chunk
    activations (``inflight_microbatches``, in 1/v-stage units)."""

    has_wgrad = False

    def __init__(self, v: int):
        if v < 2:
            raise ValueError("interleaved 1f1b needs v >= 2 virtual "
                             f"stages per rank (got {v})")
        self.v = v
        self.name = f"1f1b_i{v}"

    def _full_table(self, n_stages, n_microbatches):
        return _interleaved_full_table(n_stages, n_microbatches, self.v)


class ZeroBubbleSchedule(_TableSchedule):
    """Zero-bubble 1F1B (ZB-H1 with a bounded wgrad backlog): the
    backward splits into dgrad ('B', frees the stored input and sends the
    activation cotangent on) and wgrad ('W', drains the deferred
    parameter gradient) sub-ticks; deferred wgrads fill the drain for a
    2(P-1)/(3M+2P-2) bubble at 1f1b's min(M, P) activation footprint
    plus a backlog-deep (usually 1) param-gradient stash."""

    name = "zb"
    v = 1
    has_wgrad = True

    def _full_table(self, n_stages, n_microbatches):
        return _zb_full_table(n_stages, n_microbatches)


SCHEDULES: Dict[str, PipelineSchedule] = {
    "gpipe": GPipeSchedule(),
    "1f1b": OneFOneBSchedule(),
    "1f1b_i2": InterleavedOneFOneBSchedule(2),
    "zb": ZeroBubbleSchedule(),
}


def get_schedule(name: str) -> PipelineSchedule:
    try:
        return SCHEDULES[name]
    except KeyError:
        pass
    family, v = parse_schedule(name)       # raises for unknown names
    assert family == "1f1b_i", name        # base names are all registered
    return InterleavedOneFOneBSchedule(v)


def op_tick_counts(sched: str, n_stages: int,
                   n_microbatches: int) -> Dict[str, int]:
    """Sub-tick census of the schedule's table, summed over ranks:
    forward / dgrad ('B') / wgrad ('W') / idle op counts plus the total
    tick count — the dryrun artifact's per-schedule sub-tick record."""
    table = get_schedule(sched).tick_table(n_stages, n_microbatches)
    out = {"F": 0, "B": 0, "W": 0, "idle": 0}
    for row in table:
        for op, _ in row:
            out[op] += 1
    out["ticks"] = len(table)
    return out


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def pipeline_apply(stage_fn: Callable, stage_params, x_microbatches,
                   mesh, axis: str = "pipe", extras=None,
                   batch_axes: Sequence[str] = (), schedule: str = "gpipe",
                   param_specs=None, seq_axis: str = "", tp_axis: str = ""):
    """Run x through P stages of stage_fn under the named schedule.

    stage_fn: (stage_params_local, h, extras) -> (h, aux), applied by every
      stage on its local slice of the stacked layer params; ``aux`` is a
      float32 scalar per-stage extra loss (the MoE load-balance term) that
      rides along the activation through the schedule.  It must be
      *shard-invariant* across the batch/model axes (the MoE stats are
      psum-reduced inside the router for exactly this reason).
    stage_params: pytree whose leaves have a leading stack dim divisible by
      the pipe axis size (sharded contiguously over ``axis``: stage p gets
      slice [p*L/P, (p+1)*L/P)).
    x_microbatches: (M, mb, ...) microbatched activations; the mb (batch)
      dim is sharded over ``batch_axes`` when divisible, else replicated.
    extras: pytree broadcast to every stage unsharded (e.g. rope angles
      with batch dim 1).
    schedule: 'gpipe' | '1f1b' | '1f1b_i<v>' | 'zb' (see module
      docstring).
    param_specs: optional pytree of PartitionSpecs for stage_params; the
      default shards only the stack dim over ``axis``.  Inner-mesh plans
      pass Megatron-TP / expert-sharded specs so the stage body computes
      over the model/expert axes instead of replicating.
    seq_axis: mesh axis sharding the sequence dim of x inside the stage
      (manual context parallelism; the stage body must gather KV).
    tp_axis: mesh axis the stage body runs Megatron psums over (used to
      reduce extras-cotangents; the psums themselves live in stage_fn).

    Returns ((M, mb, ...) outputs sharded like x, aux summed over
    microbatches and stages — a replicated scalar).
    """
    out, aux_mb = get_schedule(schedule).apply(
        stage_fn, stage_params, x_microbatches, mesh, axis, extras,
        batch_axes=batch_axes, param_specs=param_specs, seq_axis=seq_axis,
        tp_axis=tp_axis)
    return out, aux_mb.sum()


def make_pipelined_block_fn(cfg, rt):
    """stage_fn applying this stage's slice of the stacked layer params.

    ``extras`` carries the rope angles (batch dim 1, broadcast over the
    local microbatch).  The Runtime must have ``constrain=None``: the
    stage body runs inside a fully-manual shard_map where named-sharding
    constraints are meaningless.  Inner-mesh composition is driven by the
    Runtime fields:

      * ``rt.tp_reduce_axis``  — Megatron-TP: the layer code sees a
        head/hidden-local config (the caller shards params over the model
        axis via ``param_specs``) and ``_apply_layer`` psums the mixer/ffn
        outputs over this axis;
      * ``rt.cp_axis``         — manual context parallelism: attention
        gathers KV over this axis and offsets its causal mask;
      * ``rt.moe_impl == 'ep_manual'`` — MoE layers dispatch through
        ``core/expert.py``'s all-to-all on ``rt.expert_axis`` directly
        (we are already inside the manual mesh).

    Returns (h, aux): the per-stage sum of the MoE load-balance losses of
    this stage's layers (zeros for dense stacks), which the schedule
    threads through the ticks.
    """
    from repro.models.transformer import _apply_layer, _sig

    sig = _sig(cfg, 0)
    cfg_stage = cfg
    if rt.tp_reduce_axis:
        # Megatron-TP inside the manual mesh: the stage body sees *local*
        # head/hidden shapes, so hand the layer code a config with local
        # counts (head_dim pinned first — it must not be re-derived from
        # the sliced head count)
        tp = rt.pipeline_mesh.shape[rt.tp_reduce_axis]
        cfg_stage = dataclasses.replace(
            cfg, head_dim=cfg.head_dim_,
            n_heads=cfg.n_heads // tp,
            n_kv_heads=cfg.kv_heads // tp)

    apply = _apply_layer
    if rt.remat:
        apply = jax.checkpoint(_apply_layer, static_argnums=(0, 1, 5))

    def stage_fn(stage_params, h, rope_ang):
        # stage_params: {'layers': pytree stacked (L_per_stage, ...)}
        def body(carry, lp):
            h_, aux_ = carry
            h2, _, a = apply(cfg_stage, sig, lp, h_, rope_ang, rt)
            return (h2, aux_ + a), None
        (h, aux), _ = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)), stage_params["layers"])
        return h, aux

    return stage_fn


def measure_bubble_fraction(step_for_m: Callable[[int], Callable[[], object]],
                            n_stages: int, microbatches: int,
                            m2: Optional[int] = None,
                            n_iter: int = 3, sched: str = "gpipe") -> dict:
    """Empirically estimate the pipeline bubble from wall time.

    ``step_for_m(M)`` returns a zero-arg compiled callable running the
    pipelined step with M microbatches at *fixed microbatch size* (total
    batch grows with M), so t(M) = t_tick * (M + P - 1) + overhead is
    linear in M.  A two-point fit recovers t_tick, and

        bubble_measured = (P - 1) * t_tick / t(M)

    which equals (P-1)/(M+P-1) up to the constant overhead term — the
    executable counterpart of ``bubble_fraction`` / the cost model's
    per-schedule bubble charge.

    Schedule generalization: d(total ticks)/dM is v for interleaved
    (t(M) = t_tick*(vM + P - 1)) and 3 for zb (t(M) = t_tick*(3M+2P-2)),
    so the fitted slope is divided by that coefficient before applying
    the schedule's drain numerator ((P-1), or 2(P-1) for zb).  The
    record carries ``virtual_stages`` so downstream artifacts can
    validate the interleaved probe against (P-1)/(vM+P-1).

    On a noisy host the two-point fit can come out non-increasing
    (t(2M) <= t(M)); that is *not* a zero bubble, it is a failed fit —
    the record flags it as ``fit_unreliable`` so downstream consumers
    (dryrun artifacts, BENCH_pipeline.json, the tier-1 probe test) can
    retry or discard instead of trusting a fabricated 0.0.
    """
    m1 = microbatches
    m2 = m2 or 2 * m1

    def timed(fn):
        fn()                                   # compile / warm up
        best = float("inf")
        for _ in range(n_iter):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    t1 = timed(step_for_m(m1))
    t2 = timed(step_for_m(m2))
    unreliable = t2 <= t1 or t1 <= 0
    family, v = parse_schedule(sched)
    ticks_per_m = 3 if family == "zb" else v
    drain = 2 * (n_stages - 1) if family == "zb" else n_stages - 1
    t_tick = max((t2 - t1) / (m2 - m1), 0.0) / ticks_per_m
    measured = drain * t_tick / t1 if t1 > 0 else 0.0
    return {
        "pp": n_stages, "microbatches": m1, "sched": sched,
        "virtual_stages": v,
        "t_step_s": t1, "t_step_2m_s": t2, "t_tick_s": t_tick,
        "bubble_predicted": bubble_fraction(n_stages, m1, sched),
        "bubble_measured": measured,
        "fit_unreliable": bool(unreliable),
    }
