"""Pipeline parallelism: GPipe schedule over a mesh axis via shard_map +
collective_permute (ppermute), jax-native (no NCCL p2p emulation).

Each device along the ``pipe`` axis owns one *stage* = a contiguous group
of layers (the stacked layer params are sharded over the pipe axis on
their leading/stack dim, so stage p holds layers [p*L/P, (p+1)*L/P)).  A
minibatch is split into M microbatches; for ``M + P - 1`` ticks every
stage computes on its current activation and ppermutes it to the next
stage.  Ticks where a stage holds no valid microbatch are the *pipeline
bubble* — fraction (P-1)/(M+P-1), exactly the term the paper's cost model
charges (``core/costmodel.py``).

The schedule composes with data parallelism: ``pipeline_apply`` shard_maps
over the *full* mesh, with microbatch activations sharded over the batch
axes (``x_spec``) and stage params sharded over ``axis`` only — GSPMD
all-gathers FSDP-sharded params at entry, and the shard_map transpose
psums parameter cotangents over the batch axes on the way back.

Differentiable: shard_map + ppermute have transpose rules, so the same
function trains under jax.grad (the backward pass runs the reverse
schedule automatically).
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):          # jax >= 0.6
    def _shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                   # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _sm

    def _shard_map(f, mesh, in_specs, out_specs):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def batch_axes_spec(mesh, axes: Sequence[str], dim_size: int) -> Tuple[str, ...]:
    """The prefix of ``axes`` that divides ``dim_size`` (fit-or-drop).

    Mirrors ``parallel._fit_spec``: when the microbatch row count cannot
    occupy the data axis (e.g. global_batch 8 split into 8 microbatches of
    1 row), the batch dim is kept replicated and the compute is redundant
    across that axis — correct, just not data-parallel.
    """
    keep = []
    for a in axes:
        n = mesh.shape[a]
        if n > 1 and dim_size % n == 0 and dim_size >= n:
            keep.append(a)
            dim_size //= n
    return tuple(keep)


def pipeline_apply(stage_fn: Callable, stage_params, x_microbatches,
                   mesh, axis: str = "pipe", extras=None,
                   batch_axes: Sequence[str] = ()):
    """Run x through P stages of stage_fn under a GPipe schedule.

    stage_fn: (stage_params_local, h, extras) -> (h, aux), applied by every
      stage on its local slice of the stacked layer params; ``aux`` is a
      float32 scalar per-stage extra loss (the MoE load-balance term) that
      rides along the activation through the schedule.
    stage_params: pytree whose leaves have a leading stack dim divisible by
      the pipe axis size (sharded contiguously over ``axis``: stage p gets
      slice [p*L/P, (p+1)*L/P)).
    x_microbatches: (M, mb, ...) microbatched activations; the mb (batch)
      dim is sharded over ``batch_axes`` when divisible, else replicated.
    extras: pytree broadcast to every stage unsharded (e.g. rope angles
      with batch dim 1).
    Returns ((M, mb, ...) outputs sharded like x, aux summed over
    microbatches and stages — a replicated scalar).
    """
    n_stages = mesh.shape[axis]
    kept = batch_axes_spec(mesh, batch_axes, x_microbatches.shape[1])
    x_spec = P(None, kept if len(kept) > 1 else (kept[0] if kept else None))

    def per_stage(params_local, xs, extras_local):
        # params_local: (L/P, ...) stage slice; xs: (M, local_mb, ...)
        stage = jax.lax.axis_index(axis)
        M = xs.shape[0]
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)          # activation in flight
        # its running aux loss — carried as shape (1,), never a scalar:
        # scalar shard_map residuals break the jax<=0.4 transpose (they
        # cannot take the residuals' dim-0 sharding)
        aux_state = jnp.zeros((1,), jnp.float32)
        outputs = jnp.zeros_like(xs)
        aux_out = jnp.zeros((M,), jnp.float32)

        def tick(carry, t):
            state, aux_state, outputs, aux_out = carry
            # stage 0 ingests microbatch t (while valid)
            inject = xs[jnp.minimum(t, M - 1)]
            h = jnp.where(stage == 0, inject, state)
            a = jnp.where(stage == 0, 0.0, aux_state)
            h, a_stage = stage_fn(params_local, h, extras_local)
            a = a + a_stage.astype(jnp.float32).reshape((1,))
            # last stage emits microbatch t - (P-1)
            out_slot = t - (n_stages - 1)
            valid = (out_slot >= 0) & (out_slot < M)
            emit = valid & (stage == n_stages - 1)
            outputs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_slice(
                    o, h[None], (jnp.maximum(out_slot, 0),) + (0,) * h.ndim),
                lambda o: o, outputs)
            aux_out = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_slice(
                    o, a, (jnp.maximum(out_slot, 0),)),
                lambda o: o, aux_out)
            # hand activation (+ its aux so far) to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = jax.lax.ppermute(h, axis, perm)
            aux_state = jax.lax.ppermute(a, axis, perm)
            return (state, aux_state, outputs, aux_out), None

        (state, aux_state, outputs, aux_out), _ = jax.lax.scan(
            tick, (state, aux_state, outputs, aux_out),
            jnp.arange(M + n_stages - 1))
        # only the last stage's buffer holds real outputs; select+broadcast.
        # aux leaves as the (M,) per-microbatch vector, reduced outside the
        # shard_map — a scalar output that doubles as a backward residual
        # trips jax<=0.4's transpose (scalars cannot take the residuals'
        # dim-0 sharding)
        mask = (stage == n_stages - 1).astype(outputs.dtype)
        outputs = jax.lax.psum(outputs * mask, axis)
        aux_mb = jax.lax.psum(
            aux_out * (stage == n_stages - 1).astype(jnp.float32), axis)
        return outputs, aux_mb

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    espec = jax.tree.map(lambda _: P(), extras)
    fn = _shard_map(per_stage, mesh, in_specs=(pspec, x_spec, espec),
                    out_specs=(x_spec, P()))
    outputs, aux_mb = fn(stage_params, x_microbatches, extras)
    return outputs, aux_mb.sum()


def make_pipelined_block_fn(cfg, rt):
    """stage_fn applying this stage's slice of the stacked layer params.

    ``extras`` carries the rope angles (batch dim 1, broadcast over the
    local microbatch).  The Runtime must have ``constrain=None``: the
    stage body runs inside a fully-manual shard_map where named-sharding
    constraints are meaningless.  Returns (h, aux): the per-stage sum of
    the MoE load-balance losses of this stage's layers (zeros for dense
    stacks), which ``pipeline_apply`` threads through the schedule.
    """
    from repro.models.transformer import _apply_layer, _sig

    sig = _sig(cfg, 0)
    apply = _apply_layer
    if rt.remat:
        apply = jax.checkpoint(_apply_layer, static_argnums=(0, 1, 5))

    def stage_fn(stage_params, h, rope_ang):
        # stage_params: {'layers': pytree stacked (L_per_stage, ...)}
        def body(carry, lp):
            h_, aux_ = carry
            h2, _, a = apply(cfg, sig, lp, h_, rope_ang, rt)
            return (h2, aux_ + a), None
        (h, aux), _ = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)), stage_params["layers"])
        return h, aux

    return stage_fn


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def measure_bubble_fraction(step_for_m: Callable[[int], Callable[[], object]],
                            n_stages: int, microbatches: int,
                            m2: Optional[int] = None,
                            n_iter: int = 3) -> dict:
    """Empirically estimate the pipeline bubble from wall time.

    ``step_for_m(M)`` returns a zero-arg compiled callable running the
    pipelined step with M microbatches at *fixed microbatch size* (total
    batch grows with M), so t(M) = t_tick * (M + P - 1) + overhead is
    linear in M.  A two-point fit recovers t_tick, and

        bubble_measured = (P - 1) * t_tick / t(M)

    which equals (P-1)/(M+P-1) up to the constant overhead term — the
    executable counterpart of ``bubble_fraction`` / the cost model's GPipe
    charge.
    """
    m1 = microbatches
    m2 = m2 or 2 * m1

    def timed(fn):
        fn()                                   # compile / warm up
        best = float("inf")
        for _ in range(n_iter):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    t1 = timed(step_for_m(m1))
    t2 = timed(step_for_m(m2))
    t_tick = max((t2 - t1) / (m2 - m1), 0.0)
    measured = (n_stages - 1) * t_tick / t1 if t1 > 0 else 0.0
    return {
        "pp": n_stages, "microbatches": m1,
        "t_step_s": t1, "t_step_2m_s": t2, "t_tick_s": t_tick,
        "bubble_predicted": bubble_fraction(n_stages, m1),
        "bubble_measured": measured,
    }
