"""jax 0.4 / 0.6 API compatibility shims, defined once.

The manual-SPMD modules (``core/pipeline.py``, ``core/expert.py``) and the
GSPMD plumbing (``core/parallel.py``) each need entry points that jax
renamed between 0.4.x and 0.6:

  * ``shard_map``  — moved from ``jax.experimental.shard_map`` to
    ``jax.shard_map``, and the replication-check kwarg was renamed
    ``check_rep`` -> ``check_vma``.  All repo shard_maps are fully manual
    (ppermute / all_to_all schedules) and disable the check.
  * ``use_mesh``   — the ambient-mesh context manager moved from "the Mesh
    object is the context manager" to ``jax.sharding.use_mesh`` to
    ``jax.set_mesh``.

Keeping the shims here (instead of copy-pasted per module) means a jax
upgrade touches exactly one file.
"""
from __future__ import annotations

import contextlib

import jax

if hasattr(jax, "shard_map"):          # jax >= 0.6
    def shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                   # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, mesh, in_specs, out_specs):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def use_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh for jit/constraints.

    jax renamed this entry point across releases (``jax.set_mesh`` /
    ``jax.sharding.use_mesh``); on older versions the Mesh object itself is
    the context manager.  All repo code goes through this helper.
    """
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    fn = getattr(jax.sharding, "use_mesh", None)
    if fn is not None:
        return fn(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)
