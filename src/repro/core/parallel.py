"""Parallelization plan: the paper's technique as a first-class object.

The paper's central finding is that the *composition* of sharded data
parallelism (FSDP/HSDP) with model parallelism (tensor / context) determines
throughput at scale, because model parallelism shrinks the FSDP collective
group.  A ``ParallelPlan`` captures one point in that strategy space and
produces:

  * parameter PartitionSpecs (2D: FSDP axis x model axis),
  * named activation constraints consumed by the model code
    (``Runtime.constrain``),
  * batch input specs,

for any of the assigned architectures on any mesh.

Attention strategy selection (see DESIGN.md §4):
  * ``head_tp``  — Megatron-style: Q heads sharded on the model axis
                   (requires n_heads % tp == 0); KV heads sharded too when
                   divisible, else replicated (GQA).
  * ``context``  — sequence sharded on the model axis; K/V all-gathered for
                   exact attention (train/prefill).  Head-count agnostic.
Decode always shards the KV cache along *sequence* (flash-decode over the
mesh); for global_batch < data axis size the cache seq dim is sharded over
both (data, model).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.compat import use_mesh  # noqa: F401  (canonical home:
#                              core/compat.py; re-exported because every
#                              launch/test call site spells par.use_mesh)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Execution-side mixed-precision policy (dtype names, not jnp dtypes,
    so the plan stays hashable and importable without jax.numpy).

    ``param_dtype`` is the stored-parameter dtype the runtime computes
    from; master parameters always stay f32 (``init_params`` initializes
    f32 and the optimizer updates in f32 — torchtitan's
    ``MixedPrecisionPolicy`` split).  ``compute_dtype`` is the activation/
    matmul dtype, ``grad_dtype`` the grad-accumulation/reduce dtype, and
    ``comm_dtype`` (when set) the wire dtype of the per-layer ZeRO param
    all-gathers — the emulated-fp8-comms path: quantize, gather, and
    dequantize back to ``compute_dtype`` (FSDP2's fp8 all-gather
    extension point).
    """
    name: str
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    grad_dtype: str = "float32"
    comm_dtype: str = ""                 # '' = gather at param_dtype


PRECISION_POLICIES = {
    "f32": PrecisionPolicy("f32"),
    "bf16": PrecisionPolicy("bf16", param_dtype="float32",
                            compute_dtype="bfloat16"),
    "fp8": PrecisionPolicy("fp8", param_dtype="float32",
                           compute_dtype="bfloat16",
                           comm_dtype="float8_e4m3fn"),
}


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    mesh: Mesh
    dp: Tuple[str, ...]                  # batch-dim axes ('pod','data') or ('data',)
    fsdp: Tuple[str, ...]                # param-shard axes (HSDP: ('data',))
    tp: str                              # model axis name
    attn: str                            # 'head_tp' | 'context'
    kv_tp: bool                          # shard KV heads on model axis
    shape_mode: str = "train"            # train | prefill | decode
    decode_cache_axes: Tuple[str, ...] = ("model",)
    seq_parallel_residuals: bool = True  # Megatron-SP residual stream
    pipe: str = ""                       # pipeline mesh axis ('' = no PP)
    microbatches: int = 1                # pipeline microbatches per minibatch
    pipe_sched: str = "gpipe"            # pipeline schedule: 'gpipe' |
                                         # '1f1b' | '1f1b_i<v>' | 'zb'
    zero_overlap: bool = False           # double-buffered ZeRO gather
                                         # prefetch: issue layer l+1's
                                         # param gather during layer l's
                                         # compute (needs per-block
                                         # gathering, which it implies)
    expert: str = ""                     # expert mesh axis ('' = no EP);
                                         # factored out of the data axis, so
                                         # it also appears in dp/fsdp
    precision: str = "f32"               # PRECISION_POLICIES key

    @property
    def policy(self) -> PrecisionPolicy:
        return PRECISION_POLICIES[self.precision]

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp]

    @property
    def pipe_size(self) -> int:
        return self.mesh.shape[self.pipe] if self.pipe else 1

    @property
    def ep_size(self) -> int:
        return self.mesh.shape[self.expert] if self.expert else 1

    @property
    def fsdp_no_expert(self) -> Tuple[str, ...]:
        """Param-shard axes for tensors already sharded over 'expert'
        (the non-E dims of expert stacks must not reuse the axis)."""
        return tuple(a for a in self.fsdp if a != self.expert)

    def axis_size(self, axes) -> int:
        return int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1


# The deprecated ``choose_plan`` shim (plan from an already-built mesh) is
# gone: build plans via ``repro.strategy.Strategy(...).to_plan`` — the same
# descriptor feeds the cost model, so planner rankings and SPMD lowerings
# cannot drift apart.


# ---------------------------------------------------------------------------
# spec fitting: drop axes that do not divide the dimension
# ---------------------------------------------------------------------------

def _fit_spec(spec: P, shape, mesh: Mesh) -> P:
    out = []
    for dim, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        size = shape[dim]
        for a in axes:
            n = mesh.shape[a]
            if size % n == 0 and size >= n:
                keep.append(a)
                size //= n
            # else: drop axis (dim not divisible)
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def fitted(plan: ParallelPlan, spec: P, x_or_shape):
    shape = getattr(x_or_shape, "shape", x_or_shape)
    spec = P(*(tuple(spec) + (None,) * (len(shape) - len(spec))))
    return NamedSharding(plan.mesh, _fit_spec(spec, shape, plan.mesh))


# ---------------------------------------------------------------------------
# parameter shardings
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _layer_plan_cached(cfg: ModelConfig):
    # layer_plan is an O(L^3) signature search; _mixer_kind calls it once
    # per parameter leaf of a hybrid model, so cache on the frozen config
    from repro.models.transformer import layer_plan
    return layer_plan(cfg)


def _mixer_kind(cfg: ModelConfig, path) -> str:
    """Mixer kind ('attn' | 'rwkv6' | 'mamba') of the layer owning a leaf.

    Attention and rwkv time-mix share leaf names (wk/wv/wo/wr), so specs
    must discriminate on the layer's kind, not the leaf name.  Pure stacks
    are unambiguous; hybrids recover the layer id from the prefix/blocks
    position in the tree path (each scanned block position holds layers of
    a single kind by construction — see transformer.layer_plan).
    """
    if cfg.mixer == "attn" or cfg.attn_every <= 1:
        return cfg.mixer
    _prefix, start, _period, _n_blocks = _layer_plan_cached(cfg)
    for j, p in enumerate(path[:-1]):
        name = getattr(p, "key", getattr(p, "name", str(p)))
        if name in ("prefix", "blocks"):
            idx = getattr(path[j + 1], "idx", None)
            if idx is None:
                break
            layer = idx if name == "prefix" else start + idx
            return cfg.layer_kind(layer)
    return cfg.mixer


def _param_spec(cfg: ModelConfig, plan: ParallelPlan, path: Tuple[str, ...],
                ndim: int) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path.

    Stacked block params have a leading (n_blocks,) dim -> specs are shifted
    right by one (the stack dim is never sharded).
    """
    f, m = plan.fsdp, plan.tp
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    leaf = names[-1]
    stacked = "blocks" in names
    # position of the leading stack dim (blocks[i] leaves carry one); a
    # pipeline plan shards it over the pipe axis — contiguous layer groups
    # per stage, exactly the slices core/pipeline.py's shard_map hands out
    pad = 1 if stacked else 0
    stack_entry = plan.pipe if (stacked and plan.pipe) else None
    base_ndim = ndim - pad

    def spec(*entries):
        entries = entries + (None,) * (base_ndim - len(entries))
        return P(*((stack_entry,) * pad + entries))

    in_attention = "mixer" in names
    vocab_tp = plan.attn == "head_tp"   # context plans keep vocab unsharded

    if leaf == "tok":
        return spec(m if vocab_tp else None, f)
    if leaf == "lm_head":
        return spec(f, m if vocab_tp else None)
    if leaf in ("scale", "bias") or base_ndim == 0:
        return spec()
    if leaf == "router":
        return spec(f, None)
    # MoE expert stacks (E, d, f) / (E, f, d)
    if base_ndim == 3 and leaf in ("w_up", "w_gate", "w_down"):
        if plan.expert:
            # EP: the E dim shards over the 'expert' axis permanently (no
            # gather over it — that is the point of expert parallelism);
            # the d dim ZeRO-shards over the remaining data axes and the
            # hidden dim takes the model axis
            f_ne = plan.fsdp_no_expert or None
            return spec(plan.expert,
                        f_ne if leaf != "w_down" else m,
                        m if leaf != "w_down" else f_ne)
        return spec(m, f if leaf != "w_down" else None,
                    f if leaf == "w_down" else None)
    if in_attention:
        kind = _mixer_kind(cfg, path)
        if kind == "attn":
            head_m = m if plan.attn == "head_tp" else None
            kv_m = m if plan.kv_tp else None
            if leaf == "wq":
                return spec(f, head_m)
            if leaf in ("wk", "wv"):
                return spec(f, kv_m)
            if leaf == "wo":
                return spec(head_m, f)
            if leaf == "bq":
                return spec(head_m)
            if leaf in ("bk", "bv"):
                return spec(kv_m)
        elif kind == "rwkv6":
            if leaf in ("wr", "wk", "wv", "wg"):
                return spec(f, m)
            if leaf == "wo":
                return spec(m, f)
            if leaf == "u":
                return spec(m, None)
            if leaf in ("tm_w1", "td_w1"):
                return spec(f, None)
            if leaf == "td_w2":
                return spec(None, f)
            if leaf == "tm_w2":
                return spec(None, None, f)
            if leaf == "maa_x":
                return spec()
            if leaf == "maa_rkvwg":
                return spec(None, None)
            if leaf == "w0":
                return spec()
        elif kind == "mamba":
            if leaf in ("w_x_in", "w_z_in"):
                return spec(f, m)
            if leaf == "conv_w":
                return spec(None, m)
            if leaf in ("conv_b", "b_dt", "D"):
                return spec(m)
            if leaf == "w_x":
                return spec(m, None)
            if leaf == "w_dt":
                return spec(None, m)
            if leaf == "A_log":
                return spec(m, None)
            if leaf == "w_out":
                return spec(m, f)
    # dense / shared-expert / rwkv channel-mix FFN (2D)
    ffn_m = m if plan.attn == "head_tp" else None
    if leaf in ("w_up", "w_gate"):
        return spec(f, ffn_m)
    if leaf == "w_down":
        return spec(ffn_m, f)
    if leaf == "wk":            # rwkv channel-mix key (d, dff)
        return spec(f, ffn_m)
    if leaf == "wv":            # rwkv channel-mix value (dff, d)
        return spec(ffn_m, f)
    if leaf == "wr":
        return spec(f, None)
    if leaf in ("maa_k", "maa_r"):
        return spec()
    return spec()


def param_shardings(cfg: ModelConfig, plan: ParallelPlan, params_shape):
    """Tree of NamedShardings matching ``jax.eval_shape(init_params, ...)``."""
    def one(path, leaf):
        spec = _param_spec(cfg, plan, path, len(leaf.shape))
        return fitted(plan, spec, leaf.shape)
    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# activation constraints (consumed via Runtime.constrain)
# ---------------------------------------------------------------------------

def activation_specs(cfg: ModelConfig, plan: ParallelPlan) -> Dict[str, P]:
    dp, m = plan.dp, plan.tp
    cp = plan.attn == "context"
    decode = plan.shape_mode == "decode"
    seq = m if (cp and not decode) else None
    # Megatron-style sequence parallelism for the residual stream: pure
    # attention architectures keep (B, S, d) activations seq-sharded on the
    # model axis between layers (all-gather at matmul entry, reduce-scatter
    # after wo/w_down — GSPMD inserts these from the constraints).  This is
    # what bounds remat-stored activations per layer boundary.  Recurrent
    # mixers (rwkv/mamba/hybrid) scan along the sequence and keep residuals
    # seq-unsharded; their per-block remat granularity bounds memory instead.
    res_seq = m if (not decode and cfg.mixer == "attn"
                    and plan.seq_parallel_residuals) else seq
    cache_seq = plan.decode_cache_axes
    return {
        # (B, S, d): sequence sharded for context-parallel plans + SP
        "act_btd": P(dp, res_seq, None),
        # (B, S, f): FFN hidden — TP for head plans, seq-sharded for CP
        "act_btf": P(dp, seq, None if cp else m),
        # (B, S, V)
        "logits": P(dp, seq, None if cp else m),
        # (B, S, H, hd)
        "heads_q": P(dp, seq, None if cp else m, None),
        "heads_kv": P(dp, seq, (m if plan.kv_tp else None) if not cp else None,
                      None),
        # decode KV cache (B, Sc, Kv, hd): sequence-sharded flash-decode
        "kv_cache": P(dp if not decode or len(cache_seq) == 1 else None,
                      cache_seq if decode else None, None, None),
        # MoE buffers (E=experts over model, capacity over data)
        "expert_buf": P(m, dp, None),
        "expert_hidden": P(m, dp, None),
        # MoE group-local dispatch tensors (G = data shards)
        "moe_group_tokens": P(dp, None, None),
        "moe_group_buf": P(dp, None, None, None),
        # rwkv
        "rwkv_heads": P(dp, None, m, None),
        "rwkv_state": P(dp, m, None, None),
        # mamba
        "mamba_inner": P(dp, seq, m),
        "mamba_state": P(dp, m, None),
    }


def make_param_gatherer(cfg: ModelConfig, plan: ParallelPlan):
    """Per-layer FSDP de-gather: constraint mapping a (sliced, per-iteration)
    layer-param pytree to its *replicated-over-fsdp* layout (model-axis
    sharding kept).  Applied inside the scan body so the all-gather is
    loop-variant and cannot be hoisted over the whole layer stack.

    When the plan's precision policy sets ``comm_dtype`` (the fp8 policy),
    floating leaves are quantized to that dtype *before* the gather
    constraint and dequantized to ``compute_dtype`` after — the all-gather
    moves fp8 bytes on the wire while compute stays bf16 (FSDP2's fp8
    all-gather extension point; ``convert_element_type`` is differentiable,
    so the backward re-gather takes the same quantized path).
    """
    import jax.numpy as jnp
    gplan = dataclasses.replace(plan, fsdp=())
    pol = plan.policy
    comm_dtype = jnp.dtype(pol.comm_dtype) if pol.comm_dtype else None
    compute_dtype = jnp.dtype(pol.compute_dtype)

    def gather(lp):
        def one(path, leaf):
            spec = _param_spec(cfg, gplan, path, len(leaf.shape))
            quant = (comm_dtype is not None and
                     jnp.issubdtype(leaf.dtype, jnp.floating))
            if quant:
                leaf = leaf.astype(comm_dtype)
            leaf = jax.lax.with_sharding_constraint(
                leaf, fitted(plan, spec, leaf.shape))
            if quant:
                leaf = leaf.astype(compute_dtype)
            return leaf
        return jax.tree_util.tree_map_with_path(one, lp)

    return gather


class _FakeKey:
    """Synthetic tree-path entries so stage param subtrees (which lack the
    'blocks' prefix of the full param tree) resolve through _param_spec."""

    def __init__(self, key=None, idx=None):
        if key is not None:
            self.key = key
        if idx is not None:
            self.idx = idx


def _normalize_spec(spec: P) -> P:
    out = []
    for e in spec:
        if isinstance(e, tuple):
            e = tuple(a for a in e if a)
            e = None if not e else (e[0] if len(e) == 1 else e)
        out.append(e)
    return P(*out)


def make_stage_param_spec_fn(cfg: ModelConfig, plan: ParallelPlan):
    """(tree_path, ndim) -> PartitionSpec for pipeline *stage* param leaves.

    The stage shard_map (``core/pipeline.py``) computes over the full
    inner mesh: the stacked leaves shard their stack dim over the pipe
    axis AND keep their model/expert sharding (the same layout
    ``_param_spec`` assigns, minus the FSDP axes — GSPMD all-gathers those
    at shard_map entry, exactly like the per-layer ZeRO gather on the
    non-pipelined path).  The stage body then runs the Megatron psums /
    expert all-to-all on the still-sharded dims instead of replicating
    the model axis (the pre-schedule-refactor waste).
    """
    gplan = dataclasses.replace(plan, fsdp=())
    prefix = (_FakeKey(key="blocks"), _FakeKey(idx=0))
    head_tp = plan.attn == "head_tp"

    def spec_fn(path, ndim):
        sp = _param_spec(cfg, gplan, prefix + tuple(path), ndim)
        if not head_tp:
            # context plans keep stage params replicated over the model
            # axis (the sequence is sharded instead); strip the model
            # entries _param_spec assigns for the GSPMD layout
            sp = P(*[None if e == plan.tp else
                     (tuple(a for a in e if a != plan.tp)
                      if isinstance(e, tuple) else e) for e in sp])
        return _normalize_spec(sp)

    return spec_fn


def make_runtime(cfg: ModelConfig, plan: ParallelPlan, shape: ShapeConfig,
                 **overrides):
    """Runtime wired to this plan's activation constraints.

    Context-parallel plans keep q seq-sharded through attention, so the
    blocked-attention path must not scan over the (sharded) query-chunk
    axis: q_chunk = S makes it a single iteration and the KV scan provides
    the memory bound.
    """
    from repro.models.layers import Runtime
    import jax.numpy as jnp
    pol = plan.policy
    kw = dict(
        param_dtype=jnp.dtype(pol.param_dtype),
        compute_dtype=jnp.dtype(pol.compute_dtype),
        grad_dtype=jnp.dtype(pol.grad_dtype),
        remat=shape.mode == "train",
        constrain=make_constrainer(cfg, plan),
        moe_impl=("ep" if plan.expert else "dropping")
        if cfg.moe.n_experts else "auto",
        moe_groups=plan.axis_size(plan.dp),
    )
    if plan.expert:
        # shard_map EP path (core/expert.py): tokens shard over every
        # mesh axis (batch axes + model) so the transpose's psums are
        # exact; the dispatch/combine all-to-all runs over expert_axis
        kw.update(expert_axis=plan.expert,
                  expert_mesh=plan.mesh,
                  expert_token_axes=tuple(plan.dp) + (plan.tp,))
    if plan.pipe and shape.mode != "decode":
        # pipeline path (train / cache-less prefill); decode steps thread a
        # cache and take the sequential scan over the pipe-sharded stack.
        # The stage body composes the full inner mesh: head_tp plans run
        # Megatron psums over the model axis, context plans shard the
        # sequence over it, and MoE layers dispatch over the expert axis.
        model_gt1 = plan.tp_size > 1
        kw.update(pipeline_axis=plan.pipe,
                  pipeline_microbatches=plan.microbatches,
                  pipeline_mesh=plan.mesh,
                  pipeline_batch_axes=tuple(plan.dp),
                  pipeline_schedule=plan.pipe_sched,
                  pipeline_param_spec_fn=make_stage_param_spec_fn(cfg, plan),
                  pipeline_tp_axis=(plan.tp if model_gt1
                                    and plan.attn == "head_tp" else ""),
                  pipeline_cp_axis=(plan.tp if model_gt1
                                    and plan.attn == "context" else ""))
    if plan.attn == "context":
        kw["attn_q_chunk"] = shape.seq_len
    # fp8 comms only exist on the per-layer gather path, so a comm_dtype
    # policy turns it on by default (still overridable); the overlap
    # transform is *defined* on that path (there is no per-layer gather
    # to double-buffer otherwise), so 'ovl' turns it on too
    per_block = overrides.pop("fsdp_gather_per_block",
                              bool(pol.comm_dtype) or plan.zero_overlap)
    if per_block and plan.fsdp:
        kw["gather_params"] = make_param_gatherer(cfg, plan)
        kw["gather_prefetch"] = plan.zero_overlap
    kw.update(overrides)
    return Runtime(**kw)


def make_constrainer(cfg: ModelConfig, plan: ParallelPlan):
    specs = activation_specs(cfg, plan)

    def constrain(name: str, x):
        spec = specs.get(name)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, fitted(plan, spec, x))

    return constrain


# ---------------------------------------------------------------------------
# batch / cache input specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, plan: ParallelPlan, batch) -> Dict:
    """NamedShardings for a batch pytree (tokens/labels/embeds/...)."""
    dp = plan.dp
    cp_seq = plan.tp if plan.attn == "context" and plan.shape_mode != "decode" else None

    def one(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        leaf_name = names[-1] if names else ""
        nd = len(leaf.shape)
        if leaf_name in ("tokens", "labels"):
            return fitted(plan, P(dp, cp_seq), leaf.shape)
        if leaf_name == "embeds":
            return fitted(plan, P(dp, cp_seq, None), leaf.shape)
        if leaf_name == "vision_embeds":
            return fitted(plan, P(dp, None, None), leaf.shape)
        if leaf_name == "position_ids":
            return fitted(plan, P(None, dp, cp_seq), leaf.shape)
        if nd == 0:
            return fitted(plan, P(), leaf.shape)
        return fitted(plan, P(dp), leaf.shape)

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_shardings(cfg: ModelConfig, plan: ParallelPlan, cache_shape):
    """Shardings for a decode cache pytree (from jax.eval_shape)."""
    specs = activation_specs(cfg, plan)

    def one(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        leaf_name = names[-1]
        stacked = "blocks" in names
        pad = (None,) if stacked else ()
        nd = len(leaf.shape) - len(pad)
        if leaf_name in ("k", "v"):
            spec = specs["kv_cache"]
        elif leaf_name == "wkv":
            # (B, H, N, N) head-sharded state; 2-D fallback for legacy carries
            spec = specs["rwkv_state"] if nd == 4 else P(plan.dp, plan.tp)
        elif leaf_name == "ssm":
            spec = specs["mamba_state"]
        elif leaf_name == "conv":
            spec = P(plan.dp, None, plan.tp)
        elif leaf_name == "x_prev":
            spec = P(plan.dp, None)
        elif leaf_name in ("kpos", "idx"):
            spec = P()
        else:
            spec = P()
        return fitted(plan, P(*(pad + tuple(spec))), leaf.shape)

    return jax.tree_util.tree_map_with_path(one, cache_shape)
