"""Analytical performance model — the paper's empirical study in closed form.

Models a distributed training step as computation + collective communication
with an explicit overlap model, over parameterized hardware generations
(V100 / A100 / H100 DGX clusters and TPU v5e pods), parallelization
strategies (FSDP/ZeRO sharded data parallel x tensor x pipeline x context
parallelism) and workloads (the paper's Llama-2 family and every assigned
architecture).

Key modeling choices, each traceable to a paper observation:

* Ring collectives are chunk-pipelined: t = (n-1) * max(B/(n*bw), alpha).
  For fixed per-layer message sizes this reproduces Fig 2b / Fig 4 — the
  effective bus bandwidth of AllGather/ReduceScatter *decays* with world
  size because per-rank chunks shrink below the latency floor.
* NCCL AllReduce has a tree algorithm whose bandwidth term does not grow
  with n (Fig 2a): t = 2B/bw + 2*log2(n)*alpha.  TPU ICI has no tree; the
  'ici' fabric uses ring reduce-scatter + all-gather (2x ring terms), but
  over a 2D torus ring bandwidth is multiplied by the number of
  independent rings (links per chip).
* Cross-island collectives (spanning >1 DGX node, or >1 pod) see the
  slower fabric: bw_eff = inter_bw / ranks_per_island, alpha_eff =
  alpha_inter (Fig 7: TP beyond a node is penalized).
* FSDP AllGather/ReduceScatter overlap with adjacent-layer compute up to
  one layer's compute time (explicit prefetch, Zhao et al.); tensor-
  parallel AllReduces are blocking (§2.1); pipeline adds the GPipe bubble.
* Power: P = idle + (peak - idle) * compute_utilization — per the paper's
  observation that GPU power draw is nearly flat (-5.9%) while utilization
  halves (§4.1).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.pipeline import (bubble_fraction, inflight_microbatches,
                                 known_schedule, virtual_stages)
from repro.perf import flops as flops_lib


# ---------------------------------------------------------------------------
# hardware generations (Table 1 + TPU v5e target)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    flops_bf16: float          # peak per chip, FLOP/s
    hbm_bw: float              # B/s
    intra_bw: float            # B/s per chip within the fast island
    inter_bw: float            # B/s per island across the slow fabric
    island: int                # chips per fast island (DGX node / pod)
    alpha_intra: float         # per-hop latency, s
    alpha_inter: float
    power_peak: float          # W per chip, fully utilized
    power_idle: float          # W per chip, stalled on comm
    rings: int = 1             # independent ring directions (torus links)
    kernel_eff: float = 0.72   # achievable fraction of peak in dense matmul
    fabric: str = "nccl"       # 'nccl' (tree AR available) | 'ici'
    # resilience: per-device MTBF, s.  Llama-3 405B saw 419 interruptions
    # in 54 days on 16k H100s -> system MTBF ~3h -> per-device ~1.8e8 s
    # (~5.7 device-years); at 10k+ devices failures are hours apart and
    # lost work + restart become a first-order throughput term (goodput()).
    mtbf: float = 1.8e8
    ckpt_bw: float = 2e9       # checkpoint write B/s per distinct writer
    #                            (per-host share of the parallel filesystem)


# kernel_eff calibration: V100 lacks FlashAttention/Hopper kernels (App. F);
# A100 reaches ~0.63 of peak on the paper's workload; H100's tripled FLOPs
# outpace its kernels' achievable efficiency on the same (small local batch)
# workload — the paper's "asymmetric improvement" (§4.4).
V100 = Hardware("V100", 125e12, 0.9e12, 300e9, 100e9, 8,
                3e-6, 14e-6, 300.0, 250.0, kernel_eff=0.35)
A100 = Hardware("A100", 312e12, 2.0e12, 600e9, 200e9, 8,
                2.5e-6, 12e-6, 400.0, 330.0, kernel_eff=0.63)
H100 = Hardware("H100", 990e12, 3.35e12, 900e9, 400e9, 8,
                2.5e-6, 12e-6, 660.0, 560.0, kernel_eff=0.48)
TPU_V5E = Hardware("TPUv5e", 197e12, 819e9, 4 * 50e9, 25e9, 256,
                   1e-6, 10e-6, 200.0, 110.0, rings=4, kernel_eff=0.70,
                   fabric="ici")

# how much adjacent-layer compute an FSDP prefetch can hide under
# (prefetch depth > 1 lets a collective span more than one layer)
PREFETCH_EFF = 1.5
GRAD_DTYPE_BYTES = 4          # fp32 gradient reduce-scatter (Megatron-style)

HARDWARE = {h.name: h for h in (V100, A100, H100, TPU_V5E)}


# ---------------------------------------------------------------------------
# precision policies (byte widths per tensor class + matmul throughput)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Precision:
    """Byte widths the analytic model charges per tensor class.

    ``param_bytes`` is the stored-parameter width (what the memory term and
    checkpoint size see), ``comm_bytes`` the width the ZeRO param gathers
    move on the wire (fp8 communicates a quantized copy of bf16-stored
    params — the FSDP2 fp8-all-gather extension point), ``act_bytes`` the
    activation width driving TP/CP/PP/MoE collective sizes, and
    ``grad_bytes`` the gradient reduce-scatter width (f32 everywhere:
    low-precision grad reduction is not modeled).  ``flops_scale``
    multiplies the hardware's bf16 matmul peak — f32 matmuls run at half
    rate on every generation modeled here.
    """
    name: str
    param_bytes: int
    comm_bytes: int
    act_bytes: int
    grad_bytes: int
    flops_scale: float


PRECISIONS = {
    "f32": Precision("f32", 4, 4, 4, 4, 0.5),
    "bf16": Precision("bf16", 2, 2, 2, 4, 1.0),
    # emulated fp8: bf16 storage/compute, fp8 on the gather wire only
    "fp8": Precision("fp8", 2, 1, 2, 4, 1.0),
}


# ---------------------------------------------------------------------------
# collectives
# ---------------------------------------------------------------------------

def _bw_alpha(hw: Hardware, n: int) -> Tuple[float, float]:
    """Effective per-rank ring bandwidth + per-hop latency for group size n."""
    if n <= hw.island:
        return hw.intra_bw * (hw.rings if hw.fabric == "ici" else 1), hw.alpha_intra
    ranks_per_island = hw.island
    return hw.inter_bw / ranks_per_island * (
        hw.rings if hw.fabric == "ici" else 1), hw.alpha_inter


def t_all_gather(hw: Hardware, bytes_total: float, n: int) -> float:
    """Ring all-gather of a tensor of bytes_total (global result size)."""
    if n <= 1:
        return 0.0
    bw, alpha = _bw_alpha(hw, n)
    return (n - 1) * max(bytes_total / (n * bw), alpha)


def t_reduce_scatter(hw: Hardware, bytes_total: float, n: int) -> float:
    return t_all_gather(hw, bytes_total, n)


def t_all_reduce(hw: Hardware, bytes_total: float, n: int) -> float:
    if n <= 1:
        return 0.0
    bw, alpha = _bw_alpha(hw, n)
    if hw.fabric == "nccl":      # tree: bandwidth term ~ independent of n
        return 2 * bytes_total / bw + 2 * math.log2(max(n, 2)) * alpha
    return 2 * (n - 1) * max(bytes_total / (n * bw), alpha)


def t_all_to_all(hw: Hardware, bytes_total: float, n: int) -> float:
    if n <= 1:
        return 0.0
    bw, alpha = _bw_alpha(hw, n)
    return (n - 1) * max(bytes_total / (n * bw), alpha)


def t_p2p(hw: Hardware, bytes_total: float, cross_island: bool) -> float:
    bw = hw.inter_bw / hw.island if cross_island else hw.intra_bw
    alpha = hw.alpha_inter if cross_island else hw.alpha_intra
    return bytes_total / bw + alpha


def bus_bandwidth_allgather(hw: Hardware, bytes_total: float, n: int) -> float:
    """NCCL-tests style busbw in B/s (for reproducing Fig 2)."""
    t = t_all_gather(hw, bytes_total, n)
    return bytes_total * (n - 1) / n / t if t else float("inf")


def bus_bandwidth_allreduce(hw: Hardware, bytes_total: float, n: int) -> float:
    t = t_all_reduce(hw, bytes_total, n)
    return 2 * bytes_total * (n - 1) / n / t if t else float("inf")


# ---------------------------------------------------------------------------
# parallelization strategy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Strategy:
    """Analytic strategy degrees.

    This is the cost model's internal view; the user-facing descriptor is
    ``repro.strategy.Strategy``, whose ``to_cost_strategy`` produces one of
    these with group sizes matching its SPMD lowering (HSDP sets
    ``fsdp_group`` to the intra-island shard group).
    """
    n_devices: int
    tp: int = 1                 # tensor-parallel degree
    pp: int = 1                 # pipeline-parallel degree
    cp: int = 1                 # context-parallel degree
    ep: int = 1                 # expert-parallel degree (an 'expert' mesh
                                # axis factored out of the data axis: the
                                # batch shards over it, expert stacks shard
                                # their E dim over it)
    zero_stage: int = 3         # 0: DDP, 2/3: sharded (paper: FSDP ~ ZeRO-2/3)
    microbatches: int = 1       # pipeline microbatches per step
    sched: str = "gpipe"        # pipeline schedule: 'gpipe' | '1f1b' |
                                # '1f1b_i<v>' | 'zb'.  gpipe/1f1b share
                                # the idle-tick bubble (1F1B caps
                                # in-flight activations at min(M, pp) at
                                # the price of one forward recompute);
                                # interleaved shrinks it to
                                # (P-1)/(vM+P-1) for v x p2p volume, zb
                                # to 2(P-1)/(3M+2P-2) via deferred wgrads
    overlap: bool = False       # double-buffered ZeRO gather prefetch
                                # ('ovl' token): the gather for layer l+1
                                # is issued at the top of layer l's
                                # compute, so each gather hides under
                                # max(t_compute, t_gather) — modeled as
                                # one extra layer of prefetch window in
                                # the FSDP exposed-comm terms.  Needs a
                                # sharded-param plan (zero_stage >= 2)
    fsdp_group: int = 0         # param-shard group size; 0 -> full dp (FSDP).
                                # HSDP: the island-local group, with the
                                # cross-island grad AR charged separately.
    precision: str = "bf16"     # PRECISIONS key.  The analytic default is
                                # bf16 — the byte widths this model always
                                # silently assumed — so calibrated anchors
                                # are unchanged; the descriptor passes the
                                # executable policy (default f32) through
                                # to_cost_strategy.

    @property
    def dp(self) -> int:
        """Total data-parallel degree (includes the expert axis)."""
        return self.n_devices // (self.tp * self.pp * self.cp)

    @property
    def fsdp_n(self) -> int:
        return self.fsdp_group or self.dp

    @property
    def model_parallel(self) -> int:
        return self.tp * self.pp * self.cp

    def valid(self) -> bool:
        return (self.precision in PRECISIONS and
                known_schedule(self.sched) and
                # a schedule token without a pipeline is not a real point
                (self.pp > 1 or self.sched == "gpipe") and
                # interleaved chunk rotation assigns microbatches to
                # ranks in groups of pp
                (virtual_stages(self.sched) == 1 or
                 self.microbatches % self.pp == 0) and
                # gather/compute overlap is a property of the sharded-
                # param gather loop; DDP has nothing to prefetch
                (not self.overlap or self.zero_stage >= 2) and
                self.dp >= 1 and
                self.dp * self.tp * self.pp * self.cp == self.n_devices and
                self.dp % self.fsdp_n == 0 and
                # expert axis is factored out of the (island-local) data
                # group — both must split into whole ranks
                self.dp % self.ep == 0 and self.fsdp_n % self.ep == 0 and
                # a pipeline with fewer microbatches than stages cannot
                # fill; pricing it would diverge from what the lowering
                # runs (the descriptor rejects mb < pp at construction)
                (self.pp == 1 or self.microbatches >= self.pp))


# ---------------------------------------------------------------------------
# goodput: failures, checkpoints, and the Young/Daly interval
# ---------------------------------------------------------------------------
# At fleet scale the hardware-failure rate grows linearly with device
# count while per-checkpoint cost depends on the *sharding*: every rank
# that holds a distinct optimizer-state shard writes in parallel, so full
# FSDP checkpoints n-ways concurrently while HSDP's replicas sit idle and
# DDP funnels everything through the model-parallel ranks.  Folding both
# into the planner objective (effective_wps) bends the throughput-vs-n
# curve down — the failure-aware diminishing-returns regime.

RESTART_BASE_S = 120.0   # detect + reschedule + reinit before the restore


def checkpoint_bytes(cfg: ModelConfig, precision: str = "bf16") -> float:
    """Global checkpoint size: stored-dtype params + fp32 Adam m/v."""
    return cfg.param_count() * (PRECISIONS[precision].param_bytes + 8)


def distinct_writers(strat: Strategy) -> int:
    """Ranks holding distinct checkpoint shards (parallel writers).

    Mirrors the memory model's opt_shard: ZeRO>=2 shards optimizer state
    over the param-shard group, so fsdp writes with every data rank,
    HSDP only with the island-local group (replicas hold copies), and
    DDP/ZeRO-0 only with the tp*pp model ranks.
    """
    shard = strat.fsdp_n if strat.zero_stage >= 2 else 1
    return max(1, min(strat.n_devices, strat.tp * strat.pp * shard))


def checkpoint_write_time(cfg: ModelConfig, hw: Hardware,
                          strat: Strategy) -> float:
    return checkpoint_bytes(cfg, strat.precision) / (
        distinct_writers(strat) * hw.ckpt_bw)


def system_mtbf(hw: Hardware, n_devices: int) -> float:
    """Mean time between failures of the whole job (any device failing)."""
    return hw.mtbf / max(1, n_devices)


def young_daly_interval(t_ckpt: float, mtbf: float) -> float:
    """Young/Daly first-order optimal checkpoint interval
    tau* = sqrt(2 * t_ckpt * M): balances checkpoint overhead
    (t_ckpt / tau, falling in tau) against expected lost work per failure
    (tau / 2M, rising in tau)."""
    return math.sqrt(2.0 * max(t_ckpt, 1e-12) * max(mtbf, 1e-12))


def goodput(t_ckpt: float, mtbf: float, t_restart: float = RESTART_BASE_S,
            interval: float = 0.0) -> float:
    """Fraction of wall-clock that is forward training progress.

    wasted = t_ckpt/tau (checkpoint stalls — 0 for a fully-async writer,
    but the snapshot+write still bounds tau from below) + (tau/2 +
    t_restart)/M (expected lost work + restart per failure).  ``interval``
    overrides the Young/Daly optimum (floored at t_ckpt — the writer
    cannot checkpoint faster than it writes).
    """
    tau = interval if interval > 0 else young_daly_interval(t_ckpt, mtbf)
    tau = max(tau, t_ckpt)
    wasted = t_ckpt / tau + (tau / 2.0 + t_restart) / max(mtbf, 1e-12)
    return max(0.0, 1.0 - wasted)


def restart_time(cfg: ModelConfig, hw: Hardware, strat: Strategy) -> float:
    """Detect/reschedule plus reading the checkpoint back."""
    return RESTART_BASE_S + checkpoint_write_time(cfg, hw, strat)


# ---------------------------------------------------------------------------
# step-time model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepReport:
    strategy: Strategy
    hardware: str
    t_step: float
    t_compute: float
    t_comm_total: float
    t_comm_exposed: float
    comm_breakdown: Dict[str, float]
    tokens: int
    wps: float                   # words(tokens)/s global
    wps_per_device: float
    tflops_per_device: float     # achieved
    mfu: float
    power_per_device: float      # W
    tokens_per_joule: float
    memory_per_device: float     # bytes (params+opt+grads+activations)
    fits: bool
    # decode-mode latency percentiles (s/token); 0.0 for train/prefill
    # pricing, where a per-token latency distribution is not meaningful.
    # p50 is the steady-state decode step; p99 adds the worst-case
    # continuous-batching interference (a decode step that lands behind
    # one chunked-prefill tick waits that chunk out).
    latency_p50: float = 0.0
    latency_p99: float = 0.0
    # failure-aware throughput (train pricing; decode reports carry the
    # no-failure identity).  goodput_frac folds checkpoint overhead, lost
    # work, and restart time at the Young/Daly-optimal interval into a
    # usable fraction of wall-clock; effective_wps = wps * goodput_frac is
    # the planner objective that reproduces the failure-aware
    # diminishing-returns curve.
    t_ckpt: float = 0.0          # one checkpoint write, s (strategy-aware)
    ckpt_interval: float = 0.0   # Young/Daly-optimal interval, s
    goodput_frac: float = 1.0
    effective_wps: float = 0.0

    def row(self) -> Dict:
        d = dataclasses.asdict(self)
        d.pop("comm_breakdown")
        d.pop("strategy")
        s = self.strategy
        d.update(n=s.n_devices, tp=s.tp, pp=s.pp, cp=s.cp, ep=s.ep,
                 dp=s.dp, sched=s.sched, precision=s.precision)
        return d

    def decomposition(self) -> Dict[str, float]:
        """Per-term step-time decomposition (seconds per step).

        This is the predicted side of the telemetry DriftMonitor's
        predicted-vs-measured comparison: ``step`` is the modeled wall
        time, ``compute`` the math term, ``collective`` the *exposed*
        communication (what a measured step actually pays), ``bubble``
        the schedule residual, plus a ``comm/<kind>`` entry per nonzero
        collective in the breakdown.
        """
        bubble = max(0.0, self.t_step - self.t_compute
                     - self.t_comm_exposed)
        d = {
            "step": self.t_step,
            "compute": self.t_compute,
            "collective": self.t_comm_exposed,
            "comm_total": self.t_comm_total,
            "bubble": bubble,
        }
        for k, v in self.comm_breakdown.items():
            if v:
                d[f"comm/{k}"] = v
        return d


def _model_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    return cfg.param_count() * dtype_bytes


def step_time(cfg: ModelConfig, hw: Hardware, strat: Strategy,
              global_batch: int, seq_len: int,
              hbm_capacity: float = 80e9, train: bool = True,
              remat: bool = False) -> StepReport:
    """Analytic step time for one optimizer step (or forward, if not train)."""
    assert strat.valid(), strat
    shape = ShapeConfig("x", seq_len, global_batch,
                        "train" if train else "prefill")
    tokens = global_batch * seq_len
    L = cfg.n_layers
    d = cfg.d_model
    px = PRECISIONS[strat.precision]
    P_bytes = _model_bytes(cfg, px.param_bytes)

    # ---- compute -----------------------------------------------------------
    total_flops = flops_lib.compiled_flops(cfg, shape, remat=remat and train)
    flops_per_dev = total_flops / strat.n_devices
    t_compute = flops_per_dev / (hw.flops_bf16 * px.flops_scale *
                                 hw.kernel_eff)
    # forward is 1/4 of compute with remat (1/3 without); AG prefetch hides
    # under the *forward* layer, grad RS under the *backward* layer.
    fwd_frac = (1 / 4 if remat else 1 / 3) if train else 1.0
    t_layer_fwd = t_compute * fwd_frac / L
    t_layer_bwd = t_compute * (1 - fwd_frac) / L if train else 0.0
    if train and strat.pp > 1 and strat.sched != "gpipe":
        # every non-GPipe schedule (1f1b, interleaved, zb) bakes remat
        # into its backward: microbatch forwards are replayed just-in-
        # time through the pipe so only the warmup-depth boundary
        # activations are ever held.  Charge that one extra forward
        # pass — the memory win is not free, and the planner must see
        # the genuine bubble/memory/recompute tradeoff
        t_compute *= 1 + fwd_frac

    # per-device local batch (examples)
    local_batch = max(global_batch // (strat.dp * strat.cp), 1)
    act_bytes_layer = local_batch * seq_len * d * px.act_bytes / strat.cp

    comm: Dict[str, float] = {"fsdp_ag": 0.0, "fsdp_rs": 0.0, "ddp_ar": 0.0,
                              "hsdp_ar": 0.0, "tp_ar": 0.0, "pp_p2p": 0.0,
                              "cp": 0.0, "moe_a2a": 0.0}

    # ---- sharded data parallel collectives (per layer) ---------------------
    # MoE expert stacks are split out of the uniform per-layer bytes: with
    # ep > 1 their E dim shards over the 'expert' axis permanently, so the
    # ZeRO AllGather/ReduceScatter covers only the local 1/ep slice and
    # runs over the reduced (data-only) group n_fsdp/ep — the lever that
    # makes EP overtake pure FSDP once expert-param gathers cross islands.
    layer_param_bytes = P_bytes / L / (strat.tp * strat.pp)
    mult = 3 if cfg.glu else 2
    n_moe = sum(cfg.is_moe_layer(i) for i in range(L))
    expert_bytes = (n_moe * cfg.moe.n_experts * mult * d *
                    cfg.moe.expert_d_ff * px.param_bytes
                    ) if cfg.moe.n_experts else 0.0
    dense_layer_bytes = (P_bytes - expert_bytes) / L / (strat.tp * strat.pp)
    moe_layer_bytes = (expert_bytes / n_moe / (strat.tp * strat.pp)
                       if n_moe else 0.0)
    n_dp = strat.dp
    n_fsdp = strat.fsdp_n       # param-shard group (== dp unless HSDP)
    if strat.zero_stage >= 2 and n_fsdp > 1:
        # AllGather params fwd (+ bwd re-gather for ZeRO-3) at the *wire*
        # width (fp8 gathers a quantized copy), ReduceScatter grads at the
        # reduce width (f32)
        n_fsdp_e = max(n_fsdp // strat.ep, 1)
        comm_scale = px.comm_bytes / px.param_bytes
        grad_scale = px.grad_bytes / px.param_bytes
        ag_dense = t_all_gather(hw, dense_layer_bytes * comm_scale, n_fsdp)
        ag_moe = t_all_gather(hw, moe_layer_bytes / strat.ep * comm_scale,
                              n_fsdp_e)
        n_ag = 2 if strat.zero_stage == 3 else 1
        rs_dense = t_reduce_scatter(
            hw, dense_layer_bytes * grad_scale, n_fsdp)
        rs_moe = t_reduce_scatter(
            hw, moe_layer_bytes / strat.ep * grad_scale, n_fsdp_e)
        comm["fsdp_ag"] = n_ag * (L * ag_dense + n_moe * ag_moe)
        comm["fsdp_rs"] = (L * rs_dense + n_moe * rs_moe) if train else 0.0
        # double-buffered gather prefetch ('ovl'): issuing layer l+1's
        # gather at the *top* of layer l's compute decouples the gather
        # deadline from its issue point by one full layer — each gather
        # costs max(t_compute, t_gather) instead of serializing, i.e.
        # the hiding window widens by t_layer on top of the baseline
        # prefetch depth
        prefetch = PREFETCH_EFF + (1.0 if strat.overlap else 0.0)
        win_fwd = prefetch * t_layer_fwd
        win_bwd = prefetch * t_layer_bwd
        n_dense_l = L - n_moe

        def _exposed_ag(win):
            return (n_dense_l * max(0.0, ag_dense - win) +
                    n_moe * max(0.0, ag_dense + ag_moe - win))

        exposed_fsdp = _exposed_ag(win_fwd)
        if strat.zero_stage == 3:
            exposed_fsdp += _exposed_ag(win_bwd)
        if train:
            exposed_fsdp += (
                n_dense_l * max(0.0, rs_dense - win_bwd) +
                n_moe * max(0.0, rs_dense + rs_moe - win_bwd))
        if train and n_fsdp < n_dp:
            # HSDP: gradient shards all-reduced across the dp//n_fsdp
            # replicas once per step, ring over the slow inter-island
            # fabric shared by the island's n_fsdp concurrent rings.
            replicas = n_dp // n_fsdp
            grad_shard = (layer_param_bytes * L * px.grad_bytes /
                          px.param_bytes / n_fsdp)
            # every chip in the island — n_fsdp data ranks x tp*cp model
            # ranks — holds a distinct shard and rings concurrently over
            # the shared cross-island fabric (same sharing as _bw_alpha)
            island_ranks = n_fsdp * strat.tp * strat.cp
            bw = hw.inter_bw / island_ranks * (
                hw.rings if hw.fabric == "ici" else 1)
            comm["hsdp_ar"] = 2 * (replicas - 1) * max(
                grad_shard / (replicas * bw), hw.alpha_inter)
            # overlaps the backward tail like DDP, but spans fewer layers
            exposed_fsdp += 0.5 * comm["hsdp_ar"]
    elif n_dp > 1 and train:
        comm["ddp_ar"] = t_all_reduce(
            hw, cfg.param_count() * px.grad_bytes, n_dp)
        # DDP grad all-reduce overlaps with backward (non-blocking, §2.1)
        exposed_fsdp = max(0.0, comm["ddp_ar"] - PREFETCH_EFF * t_compute * 2 / 3)
    else:
        exposed_fsdp = 0.0

    # ---- tensor parallel (blocking) ----------------------------------------
    if strat.tp > 1:
        # Megatron: 2 AllReduces fwd (+2 bwd) per layer over activations
        ars_per_layer = 2 * (3 if train else 1)
        t_ar = t_all_reduce(hw, act_bytes_layer, strat.tp)
        comm["tp_ar"] = L * ars_per_layer * t_ar
        exposed_tp = comm["tp_ar"]          # blocking / on critical path
    else:
        exposed_tp = 0.0

    # ---- context parallel ---------------------------------------------------
    if strat.cp > 1:
        # ring attention: pass KV around the cp ring each layer
        kv_bytes = local_batch * seq_len / strat.cp * cfg.kv_heads * \
            cfg.head_dim_ * px.act_bytes * 2
        t_ring = (strat.cp - 1) * t_p2p(hw, kv_bytes, strat.cp > hw.island)
        comm["cp"] = L * t_ring * (3 if train else 1)
        exposed_cp = 0.25 * comm["cp"]       # mostly overlapped with attn math
    else:
        exposed_cp = 0.0

    # ---- MoE all-to-all ------------------------------------------------------
    exposed_moe = 0.0
    if cfg.moe.n_experts:
        tok_bytes = (tokens / strat.dp / strat.cp) * cfg.moe.top_k * \
            cfg.moe.capacity_factor * d * px.act_bytes
        # the dispatch/combine exchange crosses the expert-sharding group:
        # the explicit 'expert' axis when ep > 1, else the model axis (the
        # GSPMD dropping path reshards the (E, C, d) buffer over the whole
        # 'model' axis — sized tp * cp, since context plans fold tp into
        # cp; with no expert and no model axis the capacity dim stays
        # data-local — no a2a)
        ep_group = (strat.ep if strat.ep > 1
                    else min(strat.tp * strat.cp, cfg.moe.n_experts))
        if ep_group > 1:
            # island crossing is set by the ranks the group spans on the
            # device grid — 'model' is innermost, so an expert group of
            # size ep spans ep * tp * cp consecutive ranks
            span = ep_group * strat.tp * strat.cp if strat.ep > 1 \
                else strat.tp * strat.cp
            bw, alpha = _bw_alpha(hw, span)
            t_a2a = 2 * (ep_group - 1) * max(
                tok_bytes / (ep_group * bw), alpha)  # dispatch + combine
            comm["moe_a2a"] = n_moe * t_a2a * (3 if train else 1)
            exposed_moe = 0.5 * comm["moe_a2a"]

    # ---- pipeline ------------------------------------------------------------
    bubble = 0.0
    if strat.pp > 1:
        m = strat.microbatches          # valid() guarantees m >= pp
        # per-schedule bubble: GPipe and 1F1B idle the same tick fraction
        # ((P-1)/(M+P-1)) at equal per-tick cost — 1F1B reorders the
        # bubble to cap in-flight activations, it does not shrink it.
        # Interleaved ((P-1)/(vM+P-1)) and zb (2(P-1)/(3M+2P-2))
        # genuinely shrink it — interleaved pays in p2p volume below
        bubble_frac = bubble_fraction(strat.pp, m, strat.sched)
        v = virtual_stages(strat.sched)
        act_boundary = local_batch * seq_len * d * px.act_bytes / m
        # v virtual stages per rank: every microbatch crosses the ring v
        # times — pp*v - 1 boundary hops instead of pp - 1
        comm["pp_p2p"] = (strat.pp * v - 1) * m * t_p2p(
            hw, act_boundary, strat.pp * strat.tp > hw.island) * (2 if train else 1)
        bubble = bubble_frac            # fraction of step, applied below
    exposed_pp = comm["pp_p2p"] * 0.5

    t_comm_total = sum(comm.values())
    t_exposed = exposed_fsdp + exposed_tp + exposed_cp + exposed_moe + exposed_pp
    t_step = (t_compute + t_exposed) / max(1e-9, (1 - bubble))

    # ---- memory ---------------------------------------------------------------
    # ZeRO shards over the param-shard group (n_fsdp == dp unless HSDP,
    # where replicas across islands each hold a full shard set).
    opt_shard = strat.tp * strat.pp * (n_fsdp if strat.zero_stage >= 2 else 1)
    mem = (P_bytes / (strat.tp * strat.pp)) / (n_fsdp if strat.zero_stage >= 3 else 1)
    mem += px.grad_bytes * cfg.param_count() / (strat.tp * strat.pp) / \
        (n_fsdp if strat.zero_stage >= 2 else 1)    # grads at reduce width
    mem += 8 * cfg.param_count() / opt_shard       # adam m+v fp32
    if train:
        # remat-boundary activations.  With a pipeline this is the
        # schedule's lever: each stage holds the boundary activations of
        # every microbatch awaiting backward — all M under GPipe, at most
        # P under 1F1B (warmup depth) — so the per-stage footprint scales
        # by inflight/M.  This is what flips ``fits`` between schedules.
        if strat.pp > 1:
            inflight = inflight_microbatches(strat.pp, strat.microbatches,
                                             strat.sched)
            # interleaved counts in-flight *chunk* activations, each a
            # 1/v slice of the rank's layers — the deeper warmup window
            # holds proportionally thinner residuals
            chunk_layers = L / (strat.pp * virtual_stages(strat.sched))
            mem += chunk_layers * act_bytes_layer * \
                inflight / strat.microbatches
            if strat.sched == "zb":
                # deferred-wgrad stash: the dgrad sub-tick parks one
                # microbatch's parameter gradient until its W sub-tick
                # drains it (backlog depth 1 under the B>W>F priority)
                mem += (P_bytes / (strat.tp * strat.pp)) * \
                    (px.grad_bytes / px.param_bytes)
        else:
            mem += L * act_bytes_layer
    mem += act_bytes_layer * 4                      # working set

    # ---- throughput / power -----------------------------------------------
    wps = tokens / t_step
    model_fl = flops_lib.model_flops(cfg, shape)
    mfu = model_fl / t_step / (strat.n_devices * hw.flops_bf16)
    util = t_compute / t_step
    power = hw.power_idle + (hw.power_peak - hw.power_idle) * util
    achieved = total_flops / t_step / strat.n_devices

    # ---- failure-aware goodput ---------------------------------------------
    t_ckpt = checkpoint_write_time(cfg, hw, strat)
    mtbf = system_mtbf(hw, strat.n_devices)
    tau = young_daly_interval(t_ckpt, mtbf)
    g = goodput(t_ckpt, mtbf, t_restart=restart_time(cfg, hw, strat))

    return StepReport(
        strategy=strat, hardware=hw.name, t_step=t_step, t_compute=t_compute,
        t_comm_total=t_comm_total, t_comm_exposed=t_exposed,
        comm_breakdown=comm, tokens=tokens, wps=wps,
        wps_per_device=wps / strat.n_devices,
        tflops_per_device=achieved / 1e12, mfu=mfu,
        power_per_device=power,
        tokens_per_joule=wps / (power * strat.n_devices),
        memory_per_device=mem, fits=mem < hbm_capacity,
        t_ckpt=t_ckpt, ckpt_interval=max(tau, t_ckpt), goodput_frac=g,
        effective_wps=wps * g)


# ---------------------------------------------------------------------------
# decode-step model (serving)
# ---------------------------------------------------------------------------

def decode_step_time(cfg: ModelConfig, hw: Hardware, strat: Strategy,
                     batch: int, context_len: int,
                     hbm_capacity: float = 80e9,
                     prefill_chunk: int = 32) -> StepReport:
    """Analytic latency of one decode step (one token per sequence).

    Decode is memory-bound, not FLOP-bound: each step streams the device's
    *active* parameter shard plus the batch's KV slice from HBM, so the
    roofline is max(flops, bytes) — the reason the training objective
    (wps) misranks serving strategies, and what the planner's decode-mode
    latency objectives price instead.  Model-parallel collectives sit on
    the critical path per token: TP all-reduces are latency-dominated at
    decode's tiny activation sizes (alpha terms, not bandwidth), and a
    pipeline adds its depth in p2p hops to every token.  Throughput-side
    fields (wps, mfu, ...) are filled for the same step so one report
    serves both rankings.
    """
    assert strat.valid(), strat
    shape = ShapeConfig("x", context_len, batch, "decode")
    L, d = cfg.n_layers, cfg.d_model
    px = PRECISIONS[strat.precision]
    P_bytes = _model_bytes(cfg, px.param_bytes)

    flops = flops_lib.forward_flops(cfg, shape)
    t_flops = flops / strat.n_devices / (hw.flops_bf16 * px.flops_scale *
                                         hw.kernel_eff)

    # HBM traffic: active params (MoE reads top_k experts' rows only) and
    # the local KV slice — batch shards over (dp, cp), heads over tp,
    # layers over pp
    local_batch = max(batch // (strat.dp * strat.cp), 1)
    active_bytes = (cfg.active_param_count() * px.param_bytes /
                    (strat.tp * strat.pp))
    kv_bytes = (local_batch * context_len * (L / strat.pp) *
                cfg.kv_heads * cfg.head_dim_ * px.act_bytes * 2 / strat.tp)
    t_mem = (active_bytes + kv_bytes) / hw.hbm_bw

    comm: Dict[str, float] = {"tp_ar": 0.0, "pp_p2p": 0.0, "moe_a2a": 0.0}
    act_bytes = local_batch * d * px.act_bytes
    if strat.tp > 1:
        comm["tp_ar"] = L * 2 * t_all_reduce(hw, act_bytes, strat.tp)
    if strat.pp > 1:
        comm["pp_p2p"] = (strat.pp - 1) * t_p2p(
            hw, act_bytes, strat.pp * strat.tp > hw.island)
    if cfg.moe.n_experts:
        n_moe = sum(cfg.is_moe_layer(i) for i in range(L))
        ep_group = (strat.ep if strat.ep > 1
                    else min(strat.tp * strat.cp, cfg.moe.n_experts))
        if ep_group > 1:
            tok_bytes = (local_batch * cfg.moe.top_k *
                         cfg.moe.capacity_factor * d * px.act_bytes)
            span = (ep_group * strat.tp * strat.cp if strat.ep > 1
                    else strat.tp * strat.cp)
            bw, alpha = _bw_alpha(hw, span)
            comm["moe_a2a"] = n_moe * 2 * (ep_group - 1) * max(
                tok_bytes / (ep_group * bw), alpha)

    t_exposed = sum(comm.values())       # all on the per-token critical path
    t_token = max(t_flops, t_mem) + t_exposed

    # p99: one chunked-prefill tick of interference (continuous batching
    # admits mid-stream; the colliding decode step waits the chunk out)
    chunk_shape = ShapeConfig("x", prefill_chunk, 1, "prefill")
    t_chunk = flops_lib.forward_flops(cfg, chunk_shape) / strat.n_devices \
        / (hw.flops_bf16 * hw.kernel_eff)
    p50 = t_token
    p99 = t_token + t_chunk

    # memory: full param shard resident + KV cache + working activations
    mem = P_bytes / (strat.tp * strat.pp) / \
        (strat.fsdp_n if strat.zero_stage >= 3 else 1)
    mem += kv_bytes + act_bytes * 4

    wps = batch / t_token
    model_fl = flops_lib.model_flops(cfg, shape)
    mfu = model_fl / t_token / (strat.n_devices * hw.flops_bf16)
    util = t_flops / t_token
    power = hw.power_idle + (hw.power_peak - hw.power_idle) * util

    return StepReport(
        strategy=strat, hardware=hw.name, t_step=t_token, t_compute=t_flops,
        t_comm_total=t_exposed, t_comm_exposed=t_exposed,
        comm_breakdown=comm, tokens=batch, wps=wps,
        wps_per_device=wps / strat.n_devices,
        tflops_per_device=flops / t_token / strat.n_devices / 1e12, mfu=mfu,
        power_per_device=power,
        tokens_per_joule=wps / (power * strat.n_devices),
        memory_per_device=mem, fits=mem < hbm_capacity,
        latency_p50=p50, latency_p99=p99,
        # serving restarts are a scheduler concern, not a goodput term
        goodput_frac=1.0, effective_wps=wps)


# The deprecated ``sweep_strategies`` / ``best_strategy`` shims are gone:
# use ``repro.strategy.search`` / ``repro.strategy.best`` (the planner
# sweeps dp_mode x tp x cp x pp x ep and prices with this module).
