"""Expert parallelism: sharded all-to-all dispatch over an 'expert' mesh axis.

The paper's strongest case for "communication reorders the strategy
ranking" is MoE training, where the dispatch/combine all-to-all is the
dominant exposed-communication term.  This module makes that exchange
*executable*: a ``Strategy(ep>1)`` plan shards the MoE expert stacks over
an 'expert' mesh axis (factored out of the data axis, so the batch shards
over ``(data, expert)`` together) and routes each MoE layer through the
textbook GShard pipeline:

    route (local argsort)  ->  all-to-all (dispatch)  ->  expert FFN
                           ->  all-to-all (combine)   ->  weighted sum

The schedule body lives in ``expert_dispatch_local`` and has two entry
points:

  * ``moe_expert_parallel``  — the GSPMD path: wraps the body in its own
    shard_map over the plan's mesh (tokens sharded over every mesh axis,
    expert stacks over 'expert' only);
  * ``expert_dispatch_local`` called directly — the pipeline path: MoE
    layers inside a ``core/pipeline.py`` stage already run in a fully
    manual shard_map where the 'expert' axis is live, so the stage body
    invokes the dispatch without re-entering shard_map (this is what
    deletes the old ``ep x pp`` StrategyError).

Layout inside the shard_map (in_specs), GSPMD path:

  * tokens ``(T, d)``     — dim 0 sharded over *every* mesh axis
    (``rt.expert_token_axes`` = batch axes + model).  Each rank routes a
    disjoint token slice, so the shard_map transpose's psums of the
    replicated-parameter cotangents (router, expert stacks' unmentioned
    axes) sum *distinct* contributions — exact gradients, no scaling.
  * expert stacks — E dim over 'expert' only.  Each expert rank owns
    E/ep experts; GSPMD gathers the ZeRO-sharded non-E dims at entry
    (that per-layer gather covers a 1/ep slice over a 1/ep-sized group —
    the term ``costmodel.step_time`` prices).
  * router — replicated.

The dispatch builds a local ``(E, C, d)`` send buffer with the same
scatter-free ``_routed_take`` index maps as the grouped-dropping path
(source-rank-local capacity ``C = ceil(T_local * k * cf / E)``), then one
``jax.lax.all_to_all`` over the 'expert' axis turns it into the
``(E/ep, ep*C, d)`` receive buffer — token dropping is identical to the
GSPMD dropping impl with one dispatch group per token shard.

The aux load-balance loss is computed from *globally* psum-reduced load
statistics (``Runtime.moe_stat_axes``), so it equals the dense oracle's
value exactly — not a per-shard approximation.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map


# Trace-time dispatch accounting: which EP entry each apply_moe lowering
# took.  Incremented when a call is *traced* (once per compiled shape, not
# per executed step) — the dry-run records deltas around its lowerings so
# the artifact shows whether a decode shape really ran the all-to-all,
# took the padded path, or fell back to GSPMD dropping.
DISPATCH_STATS = {"ep_calls": 0, "ep_padded_calls": 0, "ep_fallback_calls": 0}


def dispatch_stats_snapshot() -> dict:
    return dict(DISPATCH_STATS)


def token_shards(rt) -> int:
    """Number of shards the flattened token dim splits into."""
    mesh = rt.expert_mesh
    return int(np.prod([mesh.shape[a] for a in rt.expert_token_axes] or [1]))

def can_shard_tokens(cfg, rt, n_tokens: int) -> bool:
    """True when the EP shard_map path can run for this token count.

    Every mesh axis must shard the token dim (see module docstring: this
    is what makes the transpose's psums exact), so T must split evenly
    across all of them with at least one token per rank.
    """
    if not rt.expert_axis or rt.expert_mesh is None:
        return False
    if cfg.moe.n_experts % rt.expert_mesh.shape[rt.expert_axis]:
        return False
    shards = token_shards(rt)
    return n_tokens % shards == 0 and n_tokens >= shards


def can_pad_tokens(cfg, rt) -> bool:
    """True when ``moe_expert_parallel_padded`` can serve a token count
    that ``can_shard_tokens`` rejects: the mesh/expert-divisibility
    constraints must hold — only the token count is fixable by padding."""
    return bool(rt.expert_axis and rt.expert_mesh is not None and
                cfg.moe.n_experts % rt.expert_mesh.shape[rt.expert_axis] == 0)


def moe_expert_parallel_padded(cfg, p, xf, rt):
    """EP dispatch for token counts that do not tile the mesh (decode
    batches): zero-pad the token dim up to a multiple of the shard count,
    run the normal shard_map dispatch, slice the padding back off.

    The pad rows are appended *after* every real token, and
    ``_route_capacity``'s stable argsort preserves token order within an
    expert — so wherever a pad row competes with a real token for expert
    capacity, the real token wins; padding can only ever drop padding.
    The router's aux stats do see the pad rows (their expert counts shift
    the balance loss), which is irrelevant for the decode-only shapes
    this path exists for — training shapes always satisfy
    ``can_shard_tokens``.
    """
    T, d = xf.shape
    shards = token_shards(rt)
    T_pad = max(-(-T // shards) * shards, shards)
    if T_pad == T:
        return moe_expert_parallel(cfg, p, xf, rt)
    xp = jnp.pad(xf, ((0, T_pad - T), (0, 0)))
    y, aux = moe_expert_parallel(cfg, p, xp, rt)
    return y[:T], aux


def expert_dispatch_local(cfg, router, stack, x_loc, rt, axis: str, ep: int):
    """This rank's token slice through route -> a2a -> expert FFN -> a2a ->
    combine.  Must run inside a manual shard_map where ``axis`` is a live
    mesh axis; ``rt.moe_stat_axes`` must already name the token-sharding
    axes (the router psums its load stats over them so the aux loss is
    shard-invariant).

    x_loc (T_loc, d) -> (y (T_loc, d), aux); ``stack`` holds this rank's
    E/ep slice of the expert weights.
    """
    from repro.models.moe import (_expert_ffn, _route_capacity, _routed_take,
                                  _router)

    m = cfg.moe
    T_loc, d = x_loc.shape
    k, E = m.top_k, m.n_experts
    assert E % ep == 0, (E, ep)
    # per-source-rank capacity: same formula as one dropping group of
    # T_loc tokens, so dropping behavior matches groups == token shards
    C = int(math.ceil(T_loc * k * m.capacity_factor / E))
    C = max(8, -(-C // 8) * 8)                               # pad to 8

    _, weights, ids, aux = _router(cfg, {"router": router}, x_loc, rt)
    dest, inv = _route_capacity(ids.reshape(T_loc * k), E, C)
    x_items = jnp.broadcast_to(
        x_loc[:, None], (T_loc, k, d)).reshape(T_loc * k, d)
    buf = _routed_take(x_items, inv, dest).reshape(E, C, d)
    # dispatch: (E, C, d) -> (E/ep, ep*C, d) — every rank keeps its
    # own experts' rows from all ep peers in the group
    buf = jax.lax.all_to_all(buf, axis, 0, 1, tiled=True)
    out = _expert_ffn(cfg, stack, buf, rt)                   # (E/ep, ep*C, d)
    # combine: the exact reverse exchange
    out = jax.lax.all_to_all(out, axis, 1, 0, tiled=True)
    rows = _routed_take(out.reshape(E * C, d), dest, inv)    # (T_loc*k, d)
    y = (rows.reshape(T_loc, k, d) *
         weights[..., None].astype(rows.dtype)).sum(axis=1)
    return y, aux


def moe_expert_parallel(cfg, p, xf, rt):
    """xf (T, d) -> (y (T, d), aux) through expert-sharded dispatch (the
    GSPMD entry: wraps ``expert_dispatch_local`` in its own shard_map).

    Shared experts are handled by the caller (``apply_moe``) on the plain
    GSPMD path — they are dense and need no dispatch.
    """
    T, d = xf.shape
    mesh = rt.expert_mesh
    axis = rt.expert_axis
    ep = mesh.shape[axis]
    tok_axes = tuple(rt.expert_token_axes)
    shards = token_shards(rt)
    assert T % shards == 0 and cfg.moe.n_experts % ep == 0, (T, shards, ep)

    # constraints are meaningless inside the fully-manual shard_map;
    # the psum axes make the router's balance stats global
    rt_loc = dataclasses.replace(rt, constrain=None, moe_stat_axes=tok_axes)
    stack = {n: p[n] for n in ("w_up", "w_gate", "w_down") if n in p}

    def body(router, stack_loc, x_loc):
        return expert_dispatch_local(cfg, router, stack_loc, x_loc, rt_loc,
                                     axis, ep)

    tok_spec = P(tok_axes if len(tok_axes) > 1 else tok_axes[0], None)
    stack_spec = jax.tree.map(lambda _: P(axis, None, None), stack)
    fn = _shard_map(body, mesh,
                    in_specs=(P(), stack_spec, tok_spec),
                    out_specs=(tok_spec, P()))
    return fn(p["router"], stack, xf)


def moe_expert_parallel_manual(cfg, p, xf, rt):
    """EP dispatch for callers *already inside* a manual shard_map (the
    pipeline stage body): no nested shard_map — the all-to-all runs on
    ``rt.expert_axis`` directly.  ``xf`` is this rank's local token slice
    and the expert stacks in ``p`` are this rank's E/ep slice (the
    pipeline's ``param_specs`` sharded them over the expert axis).

    Requires the caller's tokens to actually be sharded over the expert
    axis (``rt.moe_stat_axes`` contains it): with replicated tokens every
    expert rank would push duplicate rows through the a2a and the expert
    grads would overcount — ``transformer._pipeline_blocks`` validates
    the divisibility up front.
    """
    axis = rt.expert_axis
    if not axis or rt.expert_mesh is None:
        raise ValueError("moe_expert_parallel_manual needs an expert axis")
    if axis not in tuple(rt.moe_stat_axes):
        raise ValueError(
            "EP dispatch inside a pipeline stage needs the microbatch "
            f"sharded over the {axis!r} mesh axis; this microbatch is "
            "replicated (rows do not divide the batch axes) — use a "
            "larger global batch or fewer pipeline microbatches")
    ep = rt.expert_mesh.shape[axis]
    stack = {n: p[n] for n in ("w_up", "w_gate", "w_down") if n in p}
    return expert_dispatch_local(cfg, p["router"], stack, xf, rt, axis, ep)
