"""Cost-model-driven strategy planner.

``search(cfg, topology, shape)`` sweeps the executable-strategy space
(dp_mode x tp x cp x pp x ep x pipeline schedule x ZeRO stage), prices
every candidate with the
calibrated analytic model (``costmodel.step_time``), and returns ranked
``PlannedStrategy`` records whose descriptors lower to real plans via
``Strategy.to_plan``.  This replaced the old ``costmodel.sweep_strategies``
/ ``best_strategy`` pair (now deleted) and — unlike them — sweeps
context-parallel and expert-parallel degrees.

Objectives: 'wps' (tokens/s, the train/prefill default), 'mfu',
'tokens_per_joule', 'memory' (min bytes/device), and the decode-mode
latency percentiles 'p50_latency' / 'p99_latency' (min s/token; priced by
``costmodel.decode_step_time``, which ``evaluate`` routes decode shapes
through).  When no objective is named, ``search``/``resolve`` pick
'p50_latency' for ``shape.mode == "decode"`` and 'wps' otherwise — a
serving planner that ranks by training throughput would happily trade
per-token latency for batch efficiency the serving path cannot use.
``pareto_front`` keeps the strategies that are not dominated on a set of
objectives (e.g. throughput vs energy).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import costmodel as cm
from repro.core.pipeline import SCHEDULE_NAMES
from repro.strategy.descriptor import Strategy, StrategyError, parse
from repro.strategy.topology import Topology

OBJECTIVES: Dict[str, Callable[[cm.StepReport], float]] = {
    "wps": lambda r: r.wps,
    "throughput": lambda r: r.wps,
    # failure-aware throughput: wps * goodput (checkpoint overhead + lost
    # work + restarts at the Young/Daly interval, strategy-aware writer
    # parallelism).  Diverges from 'wps' at scale/low MTBF — a strategy
    # with few distinct checkpoint writers (HSDP replicas, DDP) pays more
    # per failure than one that writes n-ways (full FSDP).
    "effective_wps": lambda r: r.effective_wps,
    "goodput": lambda r: r.goodput_frac,
    "mfu": lambda r: r.mfu,
    "tokens_per_joule": lambda r: r.tokens_per_joule,
    "memory": lambda r: -r.memory_per_device,
    # latency percentiles only exist on decode-mode reports (0.0
    # elsewhere -> score -inf, so a latency objective never ranks a
    # train/prefill pricing)
    "p50_latency": lambda r: -(r.latency_p50 or float("inf")),
    "p99_latency": lambda r: -(r.latency_p99 or float("inf")),
}


def default_objective(shape: ShapeConfig) -> str:
    return "p50_latency" if shape.mode == "decode" else "wps"


@dataclasses.dataclass
class PlannedStrategy:
    """One ranked point: the descriptor, its spec string, and the price."""
    strategy: Strategy
    report: cm.StepReport
    score: float
    lowers: bool                     # Strategy.check passed on the topology

    @property
    def spec(self) -> str:
        return self.strategy.format()

    def row(self) -> Dict:
        d = self.report.row()
        d.update(spec=self.spec, score=self.score, lowers=self.lowers)
        return d


def evaluate(cfg: ModelConfig, strategy: Strategy, topology: Topology,
             shape: ShapeConfig, train: Optional[bool] = None,
             remat: bool = False) -> cm.StepReport:
    """Price one strategy on one topology with the analytic model.

    Decode shapes route to ``costmodel.decode_step_time`` (per-token
    latency roofline + latency percentiles); train/prefill shapes to
    ``costmodel.step_time``.  An explicit ``train=`` override forces the
    step-time model either way.
    """
    cost = strategy.to_cost_strategy(cfg, topology)
    if shape.mode == "decode" and train is None:
        return cm.decode_step_time(cfg, topology.hw, cost,
                                   shape.global_batch, shape.seq_len,
                                   hbm_capacity=topology.hbm)
    return cm.step_time(cfg, topology.hw, cost, shape.global_batch,
                        shape.seq_len, hbm_capacity=topology.hbm,
                        train=shape.mode == "train" if train is None
                        else train, remat=remat)


DEFAULT_PPS = (1, 2, 4, 8)
DEFAULT_EPS = (1, 2, 4, 8)
# sweep every base schedule family plus the canonical interleaved point
# (deeper interleavings are opt-in via scheds=)
DEFAULT_SCHEDS = SCHEDULE_NAMES + ("1f1b_i2",)
DEFAULT_OVERLAPS = (False, True)     # ZeRO gather/compute overlap ('ovl')
# precision is a swept degree: same mesh, dtype-scaled byte/flops terms.
# f32 is what the lowering has always run; bf16 halves params/acts on the
# wire and doubles matmul throughput, which moves every comm-driven
# crossover (EP/PP/FSDP).  fp8 (comm-only) is opt-in via precisions=.
DEFAULT_PRECISIONS = ("f32", "bf16")


def candidates(topology: Topology, global_batch: int,
               dp_modes: Sequence[str] = ("hsdp",),
               tps: Iterable[int] = (1, 2, 4, 8, 16),
               cps: Iterable[int] = (1, 2, 4, 8),
               pps: Iterable[int] = DEFAULT_PPS,
               eps: Iterable[int] = DEFAULT_EPS,
               scheds: Sequence[str] = DEFAULT_SCHEDS,
               zero_stages: Iterable[Optional[int]] = (None,),
               microbatches: int = 8,
               precisions: Sequence[str] = DEFAULT_PRECISIONS,
               overlaps: Sequence[bool] = DEFAULT_OVERLAPS
               ) -> List[Strategy]:
    """Enumerate distinct strategy descriptors viable on ``topology``.

    tp and cp share the model axis, so candidates use at most one of them
    (the tp x cp cross product would double-count the same mesh).  The
    batch filters mirror the original sweep: dp must divide the global
    batch (or be smaller than it).  ep > 1 candidates are only viable for
    MoE configs — ``search`` filters them via ``Strategy.check(cfg)``
    (``ep | n_experts``); ep stays inside the island-local data group so
    the reduced expert gathers are whole ranks.  pp > 1 candidates are
    emitted once per pipeline schedule in ``scheds`` — gpipe/1f1b share
    the bubble but differ in activation footprint (1F1B caps in-flight
    microbatches at pp), while interleaved/zb shrink the bubble itself —
    so the schedule sweep surfaces both memory-limited and bubble-limited
    crossovers.  Every sharded-param point is additionally emitted with
    the 'ovl' gather/compute-overlap variant (``overlaps``).
    """
    n = topology.n_devices
    out: List[Strategy] = []
    seen = set()
    for dp_mode in dp_modes:
        # below one island hsdp == fsdp: keep the canonical name
        mode = ("fsdp" if dp_mode == "hsdp" and n <= topology.island
                else dp_mode)
        for zero in zero_stages:
            for tp, cp in [(t, 1) for t in tps] + [(1, c) for c in cps
                                                   if c > 1]:
                for pp in pps:
                    for ep in eps:
                        model = tp * cp * pp
                        if model * ep > n or n % (model * ep):
                            continue
                        dp = n // model
                        if dp % ep:
                            continue
                        if dp > global_batch:
                            continue
                        if global_batch % dp and global_batch >= dp:
                            continue
                        mb = max(microbatches, pp) if pp > 1 else 1
                        if pp > 1 and global_batch % mb:
                            continue   # microbatch split must divide batch
                        if pp > 1 and ep > 1 and \
                                (global_batch // mb) % dp:
                            # the in-stage expert a2a needs the microbatch
                            # sharded over (data, expert) — to_plan rejects
                            continue
                        for sched in (scheds if pp > 1 else ("gpipe",)):
                            if "_i" in sched and mb % pp:
                                continue   # interleaved needs pp | mb
                            for ovl in overlaps:
                                if ovl and (mode == "ddp" or zero == 0):
                                    continue   # nothing to prefetch
                                for prec in precisions:
                                    s = Strategy(dp_mode=mode, tp=tp,
                                                 cp=cp, pp=pp, ep=ep,
                                                 zero_stage=zero,
                                                 microbatches=mb,
                                                 sched=sched, overlap=ovl,
                                                 precision=prec)
                                    if s.format() in seen:
                                        continue
                                    seen.add(s.format())
                                    out.append(s)
    return out


def search(cfg: ModelConfig, topology: Topology, shape: ShapeConfig,
           objective: Optional[str] = None, require_fits: bool = True,
           require_lowerable: bool = True,
           dp_modes: Sequence[str] = ("hsdp",),
           tps: Iterable[int] = (1, 2, 4, 8, 16),
           cps: Iterable[int] = (1, 2, 4, 8),
           pps: Iterable[int] = DEFAULT_PPS,
           eps: Iterable[int] = DEFAULT_EPS,
           scheds: Sequence[str] = DEFAULT_SCHEDS,
           zero_stages: Iterable[Optional[int]] = (None,),
           microbatches: int = 8,
           precisions: Sequence[str] = DEFAULT_PRECISIONS,
           overlaps: Sequence[bool] = DEFAULT_OVERLAPS,
           top: Optional[int] = None) -> List[PlannedStrategy]:
    """Rank executable strategies for (model, topology, shape).

    Returns PlannedStrategy records sorted by ``objective`` (best first;
    ``None`` -> mode default: 'p50_latency' for decode shapes, 'wps'
    otherwise).  ``require_lowerable`` keeps only descriptors whose
    ``to_plan`` succeeds on the topology; ``require_fits`` keeps only
    strategies whose predicted memory fits per-chip HBM — if none fit,
    the non-fitting ranking is returned anyway (callers can see *why* via
    .report.fits).
    """
    if objective is None:
        objective = default_objective(shape)
    if objective not in OBJECTIVES:
        raise StrategyError(
            f"objective {objective!r} not in {sorted(OBJECTIVES)}")
    score = OBJECTIVES[objective]
    if not cfg.moe.n_experts:
        eps = (1,)                 # ep is an MoE-only degree
    cands = candidates(topology, shape.global_batch, dp_modes=dp_modes,
                       tps=tps, cps=cps, pps=pps, eps=eps, scheds=scheds,
                       zero_stages=zero_stages, microbatches=microbatches,
                       precisions=precisions, overlaps=overlaps)
    out: List[PlannedStrategy] = []
    for s in cands:
        lowers = s.lowerable(topology, cfg)
        if require_lowerable and not lowers:
            continue
        try:
            r = evaluate(cfg, s, topology, shape)
        except StrategyError:     # unlowerable AND unpriceable (hsdp split)
            continue
        out.append(PlannedStrategy(s, r, float(score(r)), lowers))
    if require_fits and any(p.report.fits for p in out):
        out = [p for p in out if p.report.fits]
    out.sort(key=lambda p: -p.score)
    return out[:top] if top else out


def best(cfg: ModelConfig, topology: Topology, shape: ShapeConfig,
         **kw) -> Optional[PlannedStrategy]:
    ranked = search(cfg, topology, shape, **kw)
    return ranked[0] if ranked else None


def pareto_front(planned: Sequence[PlannedStrategy],
                 objectives: Sequence[str] = ("wps", "tokens_per_joule"),
                 ) -> List[PlannedStrategy]:
    """Strategies not dominated on all of ``objectives`` simultaneously."""
    fns = [OBJECTIVES[o] for o in objectives]
    pts = [(p, tuple(f(p.report) for f in fns)) for p in planned]
    front = []
    for p, v in pts:
        dominated = any(all(w[i] >= v[i] for i in range(len(v)))
                        and any(w[i] > v[i] for i in range(len(v)))
                        for q, w in pts if q is not p)
        if not dominated:
            front.append(p)
    return front


def resolve(spec: str, cfg: ModelConfig, topology: Topology,
            shape: ShapeConfig, objective: Optional[str] = None,
            **search_kw) -> Tuple[Strategy, Optional[PlannedStrategy]]:
    """CLI entry: '--strategy auto' plans, anything else parses.

    Returns (strategy, planned) — ``planned`` carries the cost report when
    the planner chose (spec == 'auto') or None for an explicit spec.
    """
    if spec == "auto":
        planned = best(cfg, topology, shape, objective=objective, **search_kw)
        if planned is None:
            raise StrategyError(
                f"planner found no viable strategy for {cfg.name} on "
                f"{topology.name} ({topology.n_devices} devices, "
                f"global_batch={shape.global_batch})")
        return planned.strategy, planned
    s = parse(spec)
    s.check(topology, cfg)
    return s, None
