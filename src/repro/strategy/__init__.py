"""Unified executable-strategy API (see descriptor.py for the design).

    from repro import strategy

    s = strategy.parse("hsdp_tp4")            # or strategy.Strategy(tp=4)
    topo = strategy.host_topology()
    plan = s.to_plan(cfg, topo, shape)        # executable Mesh + specs
    report = strategy.evaluate(cfg, s, topo, shape)   # analytic price
    ranked = strategy.search(cfg, topo, shape)        # planner
"""
from repro.strategy.descriptor import (DP_MODES, Strategy, StrategyError,
                                       format_spec, parse)
from repro.strategy.planner import (OBJECTIVES, PlannedStrategy, best,
                                    candidates, default_objective, evaluate,
                                    pareto_front, resolve, search)
from repro.strategy.topology import (Topology, build_mesh, get_topology,
                                     host_topology, pod_topology)

__all__ = [
    "DP_MODES", "OBJECTIVES", "PlannedStrategy", "Strategy", "StrategyError",
    "Topology", "best", "build_mesh", "candidates", "default_objective",
    "evaluate", "format_spec",
    "get_topology", "host_topology", "parse", "pareto_front", "pod_topology",
    "resolve", "search",
]
