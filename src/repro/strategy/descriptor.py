"""The executable-strategy descriptor: one object that predicts AND runs.

Historically the repo had two disconnected strategy representations:
``costmodel.Strategy`` (analytical tp/pp/cp degrees) and ``ParallelPlan``
(executable mesh + PartitionSpecs).  The cost model could rank strategies
the SPMD path cannot express and vice versa.  ``Strategy`` here is the
single source of truth:

  * ``to_plan(cfg, topology, shape)``  lowers to ``Mesh + ParallelPlan``;
  * ``to_cost_strategy(cfg, topology)`` feeds ``costmodel.step_time`` with
    collective group sizes derived from the *same* lowering rules;
  * ``parse`` / ``format`` round-trip compact spec strings
    (``"hsdp_tp4"``, ``"fsdp_cp8_ga2"``) for CLIs and sweep artifacts.

Semantics of the degrees (mirrors DESIGN.md §4 / core/parallel.py):

  * ``tp``  shards attention heads + FFN hidden on the mesh 'model' axis
            (Megatron).  Falls back to context mode when head counts do
            not divide — the spec still *lowers*, and the cost model is
            told the truth (it charges ring-KV, not TP all-reduces).
  * ``cp``  shards the sequence on the 'model' axis (ring/gathered-KV
            attention).  tp and cp share the single model axis, so at most
            one may exceed 1.
  * ``pp``  shards the layer stack over a 'pipe' mesh axis (contiguous
            stages) and lowers through a differentiable pipeline schedule
            in ``core/pipeline.py`` (shard_map + ppermute).  Requires a
            uniform layer stack (no prefix / period-1 ``layer_plan``), a
            layer count divisible by pp, and ``mb >= pp`` microbatches
            (under-specified mb is a StrategyError, not a silent clamp).
            The stage body computes over the full inner mesh: head_tp
            plans Megatron-shard heads/hidden inside the stage, context
            plans shard the sequence, and MoE layers dispatch over the
            expert axis — pp composes with tp, cp AND ep.
  * ``sched``  pipeline schedule: 'gpipe' (default; M microbatch
            activations in flight per stage) or '1f1b' (PipeDream-flush;
            <= pp in flight — the smaller activation footprint the cost
            model's ``mem`` term credits).  Spec token ``_1f1b``
            (``fsdp_pp4_mb8_1f1b``); only meaningful with pp > 1.
  * ``ep``  expert parallelism: an 'expert' mesh axis factored out of
            the data axis (dp_effective = dp / ep).  MoE expert stacks
            shard their E dim over it and the dispatch/combine
            all-to-all runs along it (``core/expert.py``).  Requires an
            MoE config with ``n_experts % ep == 0``; ``ep == 1`` for
            dense configs.
  * ``dp_mode``  'hsdp' shards params inside an island and replicates
            across islands (adds a 'pod' axis when the topology spans
            more than one); 'fsdp' shards over the full data axis;
            'ddp' replicates (ZeRO-0).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import costmodel as cm
from repro.core import parallel as par
from repro.core.pipeline import SCHEDULE_NAMES as SCHEDS
from repro.core.pipeline import virtual_stages
from repro.strategy.topology import Topology, build_mesh

DP_MODES = ("hsdp", "fsdp", "ddp")
_ATTN_TOKENS = {"headtp": "head_tp", "ctx": "context"}
_ATTN_FORMAT = {v: k for k, v in _ATTN_TOKENS.items()}
_INT_TOKEN = re.compile(r"^(tp|cp|pp|ep|z|mb|ga)(\d+)$")
# continuation of a '1f1b' token: specs split on '_', so the canonical
# interleaved name '1f1b_i<v>' arrives as the token pair ('1f1b', 'i<v>')
_IVS_TOKEN = re.compile(r"^i(\d+)$")
PRECISION_TOKENS = tuple(cm.PRECISIONS)   # 'f32' | 'bf16' | 'fp8'


class StrategyError(ValueError):
    """A spec that cannot be parsed, or a strategy that cannot lower."""


@dataclasses.dataclass(frozen=True)
class Strategy:
    """Backend-agnostic parallelization strategy descriptor."""
    dp_mode: str = "hsdp"            # 'hsdp' | 'fsdp' | 'ddp'
    tp: int = 1                      # tensor-parallel degree (model axis)
    cp: int = 1                      # context-parallel degree (model axis)
    pp: int = 1                      # pipeline degree ('pipe' mesh axis)
    sched: str = "gpipe"             # pipeline schedule: 'gpipe' | '1f1b'
                                     # | '1f1b_i<v>' (interleaved, v
                                     # virtual stages per rank) | 'zb'
                                     # (zero-bubble)
    ep: int = 1                      # expert-parallel degree ('expert' axis,
                                     # factored out of the data axis)
    zero_stage: Optional[int] = None  # None -> 0 for ddp, 3 otherwise
    microbatches: int = 1            # pipeline microbatches per step
    grad_accum: int = 1
    attn: Optional[str] = None       # None=auto | 'head_tp' | 'context'
    seq_parallel: bool = True        # Megatron-SP residual stream
    precision: str = "f32"           # mixed-precision policy: 'f32' (pure
                                     # f32 — what the lowering has always
                                     # run), 'bf16' (bf16 compute/params,
                                     # f32 master + grad reduce), or 'fp8'
                                     # (bf16 compute, fp8 on the ZeRO
                                     # all-gather wire).  Spec tokens
                                     # ``_bf16`` / ``_fp8``.
    overlap: bool = False            # double-buffered ZeRO gather
                                     # prefetch (spec token ``_ovl``):
                                     # the per-block gatherer for layer
                                     # l+1 is issued during layer l's
                                     # compute.  Needs sharded params
                                     # (zero_stage >= 2).

    def __post_init__(self):
        if self.precision not in PRECISION_TOKENS:
            raise StrategyError(
                f"precision {self.precision!r} not in {PRECISION_TOKENS}")
        if self.dp_mode not in DP_MODES:
            raise StrategyError(f"dp_mode {self.dp_mode!r} not in {DP_MODES}")
        for k in ("tp", "cp", "pp", "ep", "microbatches", "grad_accum"):
            if getattr(self, k) < 1:
                raise StrategyError(f"{k} must be >= 1, got {getattr(self, k)}")
        if self.attn not in (None, "head_tp", "context"):
            raise StrategyError(f"attn {self.attn!r} not in "
                                "(None, 'head_tp', 'context')")
        if self.zero_stage not in (None, 0, 2, 3):
            # ZeRO-1 (opt-state-only sharding) is expressible by neither the
            # SPMD lowering nor the cost model — rejecting it keeps the
            # predict-and-run contract honest
            raise StrategyError(
                f"zero_stage {self.zero_stage!r} not in (None, 0, 2, 3)")
        try:
            v = virtual_stages(self.sched)   # shared schedule grammar
        except ValueError as e:
            raise StrategyError(str(e)) from None
        if self.sched != "gpipe" and self.pp == 1:
            # a schedule token without a pipeline is meaningless, and
            # format() would drop it — reject to keep specs canonical
            raise StrategyError(
                f"sched={self.sched!r} needs pp > 1 (schedules pick the "
                "pipeline's tick order)")
        if self.pp > 1 and self.microbatches < self.pp:
            # fewer microbatches than stages cannot fill the pipeline; the
            # cost model used to clamp mb up to pp silently, letting the
            # analytic price and the lowering diverge — reject instead
            raise StrategyError(
                f"pp={self.pp} needs microbatches >= pp to fill the "
                f"pipeline (got mb={self.microbatches}); spec e.g. "
                f"'fsdp_pp{self.pp}_mb{2 * self.pp}'")
        if v > 1 and self.microbatches % self.pp:
            # the interleaved chunk rotation assigns microbatches to
            # ranks in groups of pp
            raise StrategyError(
                f"sched={self.sched!r} needs microbatches divisible by "
                f"pp={self.pp} (got mb={self.microbatches})")
        if self.overlap and self.zero < 2:
            raise StrategyError(
                "ovl (double-buffered ZeRO gather prefetch) needs "
                "sharded params (zero_stage >= 2); got "
                f"dp_mode={self.dp_mode!r}, zero_stage={self.zero_stage!r}")

    # ---- derived -----------------------------------------------------------

    @property
    def zero(self) -> int:
        if self.zero_stage is not None:
            return self.zero_stage
        return 0 if self.dp_mode == "ddp" else 3

    @property
    def model_axis(self) -> int:
        """Size of the SPMD 'model' mesh axis (tp and cp share it)."""
        return self.tp * self.cp

    @property
    def model_parallel(self) -> int:
        return self.tp * self.cp * self.pp

    def dp_degree(self, topology: Topology) -> int:
        """Total data-parallel degree (the 'expert' axis is part of it:
        batch and gradients shard over (data, expert) together)."""
        return topology.n_devices // self.model_parallel

    def dp_effective(self, topology: Topology) -> int:
        """Size of the 'data' mesh axis alone: dp / ep."""
        return self.dp_degree(topology) // self.ep

    def n_pods(self, topology: Topology) -> int:
        """Leading 'pod' axis size: HSDP across islands, else folded in."""
        if self.dp_mode != "hsdp" or topology.n_devices <= topology.island:
            return 1
        return topology.n_islands

    def resolved_attn(self, cfg: ModelConfig) -> str:
        """Attention mode the lowering will actually use."""
        if self.cp > 1:
            return "context"
        if self.attn is not None:
            return self.attn
        if self.tp == 1:
            return "head_tp"
        if cfg.mixer != "attn" and cfg.attn_every <= 1:
            return "head_tp"          # no attention layers at all
        return "head_tp" if cfg.n_heads % self.tp == 0 else "context"

    # ---- validation --------------------------------------------------------

    def check(self, topology: Topology,
              cfg: Optional[ModelConfig] = None) -> None:
        """Raise StrategyError if this strategy cannot lower on topology.

        Passing ``cfg`` additionally validates the model-dependent pipeline
        constraints (uniform layer stack, layer count divisible by pp);
        ``to_plan`` always does.
        """
        n = topology.n_devices
        if self.tp > 1 and self.cp > 1:
            raise StrategyError(
                "tp and cp share the single 'model' mesh axis; at most one "
                f"may exceed 1 (got tp={self.tp}, cp={self.cp})")
        if n % (self.model_axis * self.pp * self.ep):
            raise StrategyError(
                f"model axis {self.model_axis} x pipe {self.pp} x expert "
                f"{self.ep} does not divide {n} devices")
        pods = self.n_pods(topology)
        if pods > 1 and n % (pods * self.model_axis * self.pp * self.ep):
            raise StrategyError(
                f"HSDP pods={pods} x pipe={self.pp} x expert={self.ep} x "
                f"model={self.model_axis} does not divide {n} devices")
        if self.dp_degree(topology) < 1:
            raise StrategyError(
                f"model_parallel={self.model_parallel} exceeds "
                f"{n} devices")
        if pods > 1 and (self.dp_degree(topology) // pods) % self.ep:
            # the expert axis must live inside the island-local FSDP
            # group, or the reduced expert-param gather group is not a
            # whole number of ranks
            raise StrategyError(
                f"ep={self.ep} does not divide the island-local data "
                f"group {self.dp_degree(topology) // pods}")
        if cfg is not None and self.ep > 1:
            self._check_expert(cfg)
        if cfg is not None and self.pp > 1:
            self._check_pipeline(cfg)

    def _check_expert(self, cfg: ModelConfig) -> None:
        """Model-dependent ep constraints (expert-stack sharding)."""
        E = cfg.moe.n_experts
        if not E or not any(cfg.is_moe_layer(i) for i in range(cfg.n_layers)):
            raise StrategyError(
                f"ep={self.ep} needs an MoE config with routed experts; "
                f"{cfg.name} is dense (ep must be 1)")
        if E % self.ep:
            raise StrategyError(
                f"ep={self.ep} does not divide n_experts={E} "
                f"({cfg.name}); expert stacks cannot shard evenly")

    def _check_pipeline(self, cfg: ModelConfig) -> None:
        """Model-dependent pp constraints (stage assignment + the inner
        mesh the stage body must compose)."""
        from repro.models.transformer import layer_plan
        prefix, _start, period, n_blocks = layer_plan(cfg)
        if prefix or period != 1 or not n_blocks:
            raise StrategyError(
                f"pp={self.pp} needs a uniform layer stack to form stages; "
                f"{cfg.name} has layer_plan(prefix={len(prefix)}, "
                f"period={period})")
        if cfg.n_layers % self.pp:
            raise StrategyError(
                f"{cfg.n_layers} layers do not split into {self.pp} "
                "contiguous pipeline stages")
        v = virtual_stages(self.sched)
        if cfg.n_layers % (self.pp * v):
            raise StrategyError(
                f"{cfg.n_layers} layers do not split into pp={self.pp} x "
                f"v={v} virtual-stage chunks (sched={self.sched!r})")
        if cfg.rope == "mrope":
            raise StrategyError(
                "mrope angles are batch-dependent and cannot broadcast "
                "across pipeline microbatches; pp > 1 unsupported")
        ma = self.model_axis
        if ma <= 1:
            return
        # pp x tp / pp x cp composed compute: the stage body runs the
        # model-axis collectives manually (Megatron psums / gathered-KV),
        # implemented for attention stacks only
        if cfg.layer_kind(0) != "attn":
            raise StrategyError(
                f"pp={self.pp} with a model axis of {ma} runs manual "
                f"tensor/context parallelism inside the stage, which is "
                f"implemented for attention stacks only ({cfg.name} is "
                f"{cfg.layer_kind(0)})")
        if self.resolved_attn(cfg) != "head_tp":
            return          # context mode: stage params stay replicated
        if cfg.n_heads % ma or cfg.kv_heads % ma:
            raise StrategyError(
                f"pp x tp composed stage needs n_heads={cfg.n_heads} and "
                f"kv_heads={cfg.kv_heads} divisible by the model axis {ma}")
        moe_stack = cfg.is_moe_layer(0)
        if moe_stack:
            if self.ep == 1:
                raise StrategyError(
                    f"MoE expert stacks cannot shard experts over the "
                    f"model axis inside a pipeline stage; compose with "
                    f"ep<k> instead (got tp={ma}, ep=1, pp={self.pp})")
            if cfg.moe.expert_d_ff % ma:
                raise StrategyError(
                    f"pp x tp composed MoE stage needs expert_d_ff="
                    f"{cfg.moe.expert_d_ff} divisible by the model axis {ma}")
            if cfg.moe.n_shared_experts and \
                    (cfg.moe.n_shared_experts * cfg.moe.expert_d_ff) % ma:
                raise StrategyError(
                    f"pp x tp composed MoE stage needs the shared-expert "
                    f"hidden dim divisible by the model axis {ma}")
        else:
            dff = cfg.dense_d_ff or cfg.d_ff
            if dff % ma:
                raise StrategyError(
                    f"pp x tp composed stage needs d_ff={dff} divisible "
                    f"by the model axis {ma}")

    def lowerable(self, topology: Topology,
                  cfg: Optional[ModelConfig] = None) -> bool:
        try:
            self.check(topology, cfg)
            return True
        except StrategyError:
            return False

    # ---- lowering: SPMD ----------------------------------------------------

    def to_plan(self, cfg: ModelConfig, topology: Topology, shape: ShapeConfig,
                abstract: bool = False) -> par.ParallelPlan:
        """Lower to an executable ``ParallelPlan`` on this topology's mesh.

        ``abstract=True`` builds an ``AbstractMesh`` (group-size /
        PartitionSpec analysis without devices).
        """
        self.check(topology, cfg)
        if self.pp > 1 and shape.mode == "train":
            per_step = self.grad_accum * self.microbatches
            if shape.global_batch % per_step:
                raise StrategyError(
                    f"global_batch={shape.global_batch} does not split "
                    f"into grad_accum={self.grad_accum} x "
                    f"microbatches={self.microbatches}")
            if self.ep > 1:
                # the expert all-to-all inside a stage needs the
                # microbatch rows actually sharded over the expert axis
                # (fit-or-drop keeps axes in (pod, data, expert) order)
                rows = shape.global_batch // per_step
                pods = self.n_pods(topology)
                size = rows
                for n in ((pods,) if pods > 1 else ()) + \
                        (self.dp_effective(topology) // max(pods, 1),):
                    if n > 1 and size % n == 0 and size >= n:
                        size //= n
                if self.ep > 1 and (size % self.ep or size < self.ep):
                    raise StrategyError(
                        f"pp x ep: microbatch rows={rows} do not shard "
                        f"over the expert axis (ep={self.ep}) after the "
                        "data axes — grow global_batch or lower "
                        "grad_accum x microbatches")
        if self.pp > 1 and self.model_axis > 1 and cfg is not None and \
                shape.mode != "decode" and \
                self.resolved_attn(cfg) == "context" and \
                shape.seq_len % self.model_axis:
            raise StrategyError(
                f"pp x cp composed stage shards the sequence: seq_len="
                f"{shape.seq_len} must divide by the model axis "
                f"{self.model_axis}")
        pods = self.n_pods(topology)
        mesh = build_mesh(topology, model=self.model_axis, pods=pods,
                          pipe=self.pp, expert=self.ep, abstract=abstract)
        attn = self.resolved_attn(cfg)
        has_pod = pods > 1
        has_ep = self.ep > 1
        # the expert axis is factored out of data: batch (and the full
        # data-parallel gradient reduction) spans both
        dp: Tuple[str, ...] = (("pod",) if has_pod else ()) + ("data",) + \
            (("expert",) if has_ep else ())
        if self.dp_mode == "ddp" or self.zero == 0:
            fsdp: Tuple[str, ...] = ()
        elif has_pod:                 # hsdp: shard inside the island only
            fsdp = ("data",) + (("expert",) if has_ep else ())
        else:
            fsdp = dp
        kv_tp = attn == "head_tp" and cfg.kv_heads % self.model_axis == 0

        # decode cache: shard sequence over model, and over data too when
        # the batch cannot occupy the data axis (long-context, batch=1)
        data_size = topology.n_devices // (self.model_axis * self.pp)
        if shape.mode == "decode" and shape.global_batch < data_size:
            cache_axes = (("pod",) if has_pod else ()) + ("data",) + \
                (("expert",) if has_ep else ()) + ("model",)
        else:
            cache_axes = ("model",)

        return par.ParallelPlan(
            mesh=mesh, dp=dp, fsdp=fsdp, tp="model", attn=attn, kv_tp=kv_tp,
            shape_mode=shape.mode, decode_cache_axes=cache_axes,
            seq_parallel_residuals=self.seq_parallel,
            pipe="pipe" if self.pp > 1 else "",
            microbatches=self.microbatches if self.pp > 1 else 1,
            pipe_sched=self.sched,
            zero_overlap=self.overlap,
            expert="expert" if has_ep else "",
            precision=self.precision)

    # ---- lowering: cost model ----------------------------------------------

    def to_cost_strategy(self, cfg: ModelConfig,
                         topology: Topology) -> cm.Strategy:
        """The analytic view, with group sizes matching ``to_plan``.

        When the resolved attention mode is 'context', the whole model axis
        moves sequence, not heads — the cost model is charged ring-KV
        context parallelism of degree tp*cp, not TP all-reduces.  HSDP
        topologies additionally pin the FSDP collective group to the
        island ('data' axis), with the cross-island gradient all-reduce
        charged separately by ``step_time``.
        """
        attn = self.resolved_attn(cfg)
        if attn == "context":
            tp_c, cp_c = 1, self.model_axis
        else:
            tp_c, cp_c = self.model_axis, 1
        pods = self.n_pods(topology)
        dp = self.dp_degree(topology)
        if pods > 1 and dp % pods:
            raise StrategyError(
                f"HSDP dp={dp} does not split across {pods} islands; the "
                "descriptor cannot lower in this regime, so it has no "
                "coherent analytic price either")
        fsdp_group = dp // pods if pods > 1 else 0
        # mb >= pp is enforced at construction, so the microbatch count the
        # cost model's bubble term sees is exactly what the lowering runs
        return cm.Strategy(
            n_devices=topology.n_devices, tp=tp_c, pp=self.pp, cp=cp_c,
            ep=self.ep,
            zero_stage=self.zero,
            microbatches=self.microbatches, sched=self.sched,
            overlap=self.overlap,
            fsdp_group=fsdp_group, precision=self.precision)

    # ---- spec strings ------------------------------------------------------

    def format(self) -> str:
        """Canonical compact spec string; ``parse(format(s)) == s``."""
        parts = [self.dp_mode]
        for key, val in (("tp", self.tp), ("cp", self.cp), ("pp", self.pp),
                         ("ep", self.ep)):
            if val > 1:
                parts.append(f"{key}{val}")
        if self.zero_stage is not None:
            parts.append(f"z{self.zero_stage}")
        if self.microbatches > 1:
            parts.append(f"mb{self.microbatches}")
        if self.grad_accum > 1:
            parts.append(f"ga{self.grad_accum}")
        if self.sched != "gpipe":
            parts.append(self.sched)
        if self.overlap:
            parts.append("ovl")
        if self.precision != "f32":
            parts.append(self.precision)
        if self.attn is not None:
            parts.append(_ATTN_FORMAT[self.attn])
        if not self.seq_parallel:
            parts.append("nosp")
        return "_".join(parts)

    def __str__(self) -> str:
        return self.format()


def parse(spec: str) -> Strategy:
    """Parse a compact spec string into a ``Strategy``.

    Grammar: ``<dp_mode>[_tp<k>][_cp<k>][_pp<k>][_ep<k>][_z<stage>][_mb<m>]
    [_ga<g>][_gpipe|_1f1b[_i<v>]|_zb][_ovl][_f32|_bf16|_fp8][_headtp|_ctx]
    [_nosp]`` with dp_mode in {hsdp, fsdp, ddp}.  Examples: ``hsdp_tp4``,
    ``fsdp_cp8``, ``fsdp_ep8``, ``hsdp_tp2_ep4``, ``fsdp_pp4_mb8_1f1b``,
    ``fsdp_pp4_mb8_1f1b_i2``, ``fsdp_pp4_mb8_zb_ovl``, ``ddp``,
    ``fsdp_bf16``, ``hsdp_tp4_ga2_nosp``.
    """
    tokens = spec.strip().lower().split("_")
    if not tokens or tokens[0] not in DP_MODES:
        raise StrategyError(
            f"spec {spec!r} must start with one of {DP_MODES}")
    kw = {"dp_mode": tokens[0]}
    names = {"tp": "tp", "cp": "cp", "pp": "pp", "ep": "ep",
             "z": "zero_stage", "mb": "microbatches", "ga": "grad_accum"}
    for tok in tokens[1:]:
        if tok == "nosp":
            kw["seq_parallel"] = False
            continue
        if tok in SCHEDS:
            if "sched" in kw:
                raise StrategyError(
                    f"duplicate token {tok!r} in spec {spec!r}")
            kw["sched"] = tok
            continue
        m_i = _IVS_TOKEN.match(tok)
        if m_i and kw.get("sched") == "1f1b":
            # '1f1b_i<v>' split into ('1f1b', 'i<v>') — rejoin; the
            # Strategy constructor validates v >= 2 via the shared grammar
            kw["sched"] = f"1f1b_i{m_i.group(1)}"
            continue
        if tok == "ovl":
            if kw.get("overlap"):
                raise StrategyError(
                    f"duplicate token {tok!r} in spec {spec!r}")
            kw["overlap"] = True
            continue
        if tok in _ATTN_TOKENS:
            kw["attn"] = _ATTN_TOKENS[tok]
            continue
        if tok in PRECISION_TOKENS:
            if "precision" in kw:
                raise StrategyError(
                    f"duplicate token {tok!r} in spec {spec!r}")
            kw["precision"] = tok
            continue
        m = _INT_TOKEN.match(tok)
        if not m:
            raise StrategyError(
                f"bad token {tok!r} in spec {spec!r} (expected "
                "tp<k>/cp<k>/pp<k>/ep<k>/z<s>/mb<m>/ga<g>/gpipe/1f1b/"
                "1f1b_i<v>/zb/ovl/f32/bf16/fp8/headtp/ctx/nosp)")
        field = names[m.group(1)]
        if field in kw:
            raise StrategyError(f"duplicate token {tok!r} in spec {spec!r}")
        kw[field] = int(m.group(2))
    return Strategy(**kw)


def format_spec(strategy: Strategy) -> str:
    return strategy.format()
