"""Device topology: the hardware half of a (strategy, topology) pairing.

The paper's core argument is that the right parallelization strategy is a
function of the *cluster*, not just the model: island size (NVLink node /
ICI pod), fabric bandwidths, and chip count all move the optimum.  A
``Topology`` names those facts once so that

  * ``Strategy.to_plan``  builds the SPMD mesh from it (no hard-coded
    ``(16, 16)`` shapes), and
  * ``Strategy.to_cost_strategy`` / ``planner.search`` charge collectives
    for exactly the group sizes that mesh will produce.

``build_mesh`` can also build an ``AbstractMesh`` (no devices needed) so
plans for a 512-chip pod can be *analyzed* on a laptop; only execution
needs the real chips.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import costmodel as cm


@dataclasses.dataclass(frozen=True)
class Topology:
    """A cluster shape + the hardware generation that fills it."""
    name: str
    n_devices: int
    island: int                  # chips per fast island (DGX node / TPU pod)
    hardware: str = "TPUv5e"     # key into costmodel.HARDWARE
    hbm: float = 16e9            # per-chip HBM capacity, bytes
    hw_obj: Optional[cm.Hardware] = None  # explicit profile (e.g. calibrated
    #                              variant) overrides the HARDWARE lookup

    def __post_init__(self):
        assert self.n_devices >= 1 and self.island >= 1
        if self.hw_obj is None:
            assert self.hardware in cm.HARDWARE, (
                f"unknown hardware {self.hardware!r}; "
                f"known: {sorted(cm.HARDWARE)}")

    @property
    def hw(self) -> cm.Hardware:
        if self.hw_obj is not None:
            return self.hw_obj
        return cm.HARDWARE[self.hardware]

    @property
    def n_islands(self) -> int:
        return max(1, self.n_devices // self.island)


def host_topology(hardware: str = "H100", hbm: float = 80e9,
                  n_devices: Optional[int] = None) -> Topology:
    """Whatever devices this process sees, as one fast island.

    ``hardware`` picks the cost-model profile the planner uses when asked
    to rank strategies for the host mesh (CPU smoke runs have no profile of
    their own — predictions are for the named generation, execution is
    local).
    """
    import jax
    n = n_devices or len(jax.devices())
    return Topology("host", n, island=n, hardware=hardware, hbm=hbm)


def pod_topology(pods: int = 1, chips_per_pod: int = 256,
                 hardware: str = "TPUv5e", hbm: float = 16e9) -> Topology:
    """The production target: TPU v5e pod(s), DCN-connected above 1 pod."""
    name = "pod" if pods == 1 else f"multipod{pods}"
    return Topology(name, pods * chips_per_pod, island=chips_per_pod,
                    hardware=hardware, hbm=hbm)


def get_topology(name: str, **kw) -> Topology:
    """CLI entry: 'host' | 'pod' | 'multipod' | 'multipod<k>'."""
    if name == "host":
        return host_topology(**kw)
    if name == "pod":
        return pod_topology(pods=1, **kw)
    if name.startswith("multipod"):
        pods = int(name[len("multipod"):] or 2)
        return pod_topology(pods=pods, **kw)
    raise ValueError(f"unknown topology {name!r} "
                     "(expected host | pod | multipod[<k>])")


def build_mesh(topology: Topology, model: int = 1, pods: int = 1,
               pipe: int = 1, expert: int = 1, abstract: bool = False):
    """Mesh for ``topology`` with given model-, pipe- and expert-axis degrees.

    pods > 1 adds a leading 'pod' axis (HSDP: params sharded inside the
    island, replicated across pods).  pipe > 1 adds a 'pipe' axis for
    GPipe stages, placed outermost below 'pod' so stages span the slow
    fabric first (pipeline p2p is the cheapest cross-island traffic —
    the paper's argument for PP at scale).  expert > 1 adds an 'expert'
    axis *factored out of the data axis* (data = dp / expert): batch and
    gradients shard over (data, expert) together, while MoE expert stacks
    shard their E dim over 'expert' only — the dispatch/combine
    all-to-all runs along it.  It sits between 'data' and 'model' so the
    ep-group ranks are as mesh-adjacent as the model axis allows.
    ``abstract=True`` returns an ``AbstractMesh`` — enough for
    PartitionSpec/group-size analysis without any devices attached.
    """
    n = topology.n_devices
    if n % (model * pods * pipe * expert):
        raise ValueError(
            f"mesh ({pods} pods x pipe {pipe} x expert {expert} x model "
            f"{model}) does not divide {n} devices")
    data = n // (model * pods * pipe * expert)
    shape = (pods, pipe, data, expert, model)
    axes = ("pod", "pipe", "data", "expert", "model")
    keep = [i for i, (a, s) in enumerate(zip(axes, shape))
            if a in ("data", "model") or s > 1]
    shape = tuple(shape[i] for i in keep)
    axes = tuple(axes[i] for i in keep)
    if abstract:
        from jax.sharding import AbstractMesh
        return AbstractMesh(tuple(zip(axes, shape)))
    import jax
    return jax.make_mesh(shape, axes)
