from repro.data.pipeline import SyntheticSource, BinTokenSource, Batcher

__all__ = ["SyntheticSource", "BinTokenSource", "Batcher"]
