"""Token data pipeline.

Two sources:
  * ``SyntheticSource`` — deterministic pseudo-corpus (a mixture of Zipfian
    unigrams and repeated n-gram motifs so models can actually learn
    something in the example runs);
  * ``BinTokenSource``  — memory-mapped flat uint16/uint32 token files
    (the standard pretraining-data layout).

The ``Batcher`` packs documents into fixed-length sequences, builds
next-token labels, and shards the global batch across the mesh's data axes
with ``jax.device_put``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np


class SyntheticSource:
    """Infinite deterministic token stream with learnable structure."""

    def __init__(self, vocab_size: int, seed: int = 0, motif_len: int = 8,
                 n_motifs: int = 64):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        # Zipfian unigram table
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.motifs = [self.rng.integers(0, vocab_size, size=motif_len)
                       for _ in range(n_motifs)]

    def stream(self) -> Iterator[np.ndarray]:
        while True:
            if self.rng.random() < 0.5:
                yield self.motifs[int(self.rng.integers(len(self.motifs)))]
            else:
                yield self.rng.choice(self.vocab, size=16, p=self.probs)


class BinTokenSource:
    """Flat binary token file, memory-mapped; loops forever."""

    def __init__(self, path: str, dtype=np.uint16, chunk: int = 4096):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.chunk = chunk

    def stream(self) -> Iterator[np.ndarray]:
        off = 0
        n = len(self.data)
        while True:
            end = min(off + self.chunk, n)
            yield np.asarray(self.data[off:end], dtype=np.int64)
            off = end if end < n else 0


@dataclasses.dataclass
class Batcher:
    """Fixed-length batch packer with a restorable stream position.

    ``start_batch`` is the data-pipeline position: iteration replays the
    source stream from the beginning (sources are deterministic given
    their construction args) and discards that many packed batches before
    yielding — so a resumed training run sees exactly the batches an
    uninterrupted run would have seen from that step.  The checkpoint
    ``meta`` records the position as ``batches_consumed``; ``at(n)``
    builds the repositioned batcher.
    """
    source: object
    seq_len: int
    global_batch: int
    sharding: Optional[jax.sharding.NamedSharding] = None
    start_batch: int = 0

    def at(self, position: int) -> "Batcher":
        """This batcher repositioned to ``position`` packed batches in."""
        return dataclasses.replace(self, start_batch=position)

    def __iter__(self):
        buf = np.empty((0,), np.int64)
        stream = self.source.stream()
        need = self.global_batch * (self.seq_len + 1)
        position = 0
        while True:
            while len(buf) < need:
                buf = np.concatenate([buf, next(stream).astype(np.int64)])
            flat, buf = buf[:need], buf[need:]
            position += 1
            if position <= self.start_batch:
                continue
            grid = flat.reshape(self.global_batch, self.seq_len + 1)
            tokens = grid[:, :-1].astype(np.int32)
            labels = grid[:, 1:].astype(np.int32)
            batch = {"tokens": tokens, "labels": labels}
            if self.sharding is not None:
                batch = {k: jax.device_put(v, self.sharding)
                         for k, v in batch.items()}
            yield batch
