"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``."""
from repro.configs.base import ModelConfig, MoEConfig, MambaConfig, ShapeConfig, SHAPES, reduced

from repro.configs.rwkv6_1p6b import CONFIG as _rwkv6
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.qwen2_1p5b import CONFIG as _qwen2
from repro.configs.granite_20b import CONFIG as _granite
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.jamba_v01_52b import CONFIG as _jamba
from repro.configs.qwen3_0p6b import CONFIG as _qwen3
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.h2o_danube_1p8b import CONFIG as _danube
from repro.configs.llama2 import CONFIGS as _llama2

ASSIGNED = {
    c.name: c for c in (
        _rwkv6, _deepseek, _musicgen, _qwen2, _granite,
        _qwen2vl, _jamba, _qwen3, _dbrx, _danube)
}

REGISTRY = dict(ASSIGNED)
REGISTRY.update(_llama2)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs(assigned_only: bool = False):
    return sorted(ASSIGNED if assigned_only else REGISTRY)


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic sequence mixing (see DESIGN.md §4)."""
    if shape.name != "long_500k":
        return True
    if cfg.mixer in ("rwkv6", "mamba"):   # ssm / hybrid: O(1)-state decode
        return True
    return cfg.sliding_window > 0          # SWA dense: window-bounded cache


__all__ = [
    "ModelConfig", "MoEConfig", "MambaConfig", "ShapeConfig", "SHAPES",
    "reduced", "ASSIGNED", "REGISTRY", "get_config", "list_archs",
    "supports_shape",
]
