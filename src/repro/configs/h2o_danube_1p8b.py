"""H2O-Danube 1.8B — llama/mistral mix with sliding-window attention.

[arXiv:2401.16818] 24L d_model=2560 32H kv=8 d_ff=6912 vocab=32000,
sliding window 4096 (mistral-style) -> sub-quadratic, runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
    source="H2O-Danube [arXiv:2401.16818]",
)
