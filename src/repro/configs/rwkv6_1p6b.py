"""RWKV-6 "Finch" 1.6B — attention-free RNN with data-dependent decay.

[arXiv:2404.05892] 24L d_model=2048 d_ff=7168 vocab=65536, head_dim 64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # 2048 / 64 WKV heads
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    mixer="rwkv6",
    rope="none",
    glu=False,             # RWKV channel-mix is relu^2, not GLU
    act="relu2",
    rwkv_head_dim=64,
    norm="layernorm",
    source="Finch: RWKV-6 [arXiv:2404.05892]",
)
