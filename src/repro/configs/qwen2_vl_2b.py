"""Qwen2-VL-2B — VLM with M-RoPE and dynamic resolution.

[arXiv:2409.12191] 28L d_model=1536 12H kv=2 d_ff=8960 vocab=151936.
The ViT vision tower + projector are STUBBED per the brief: ``input_specs``
provides precomputed patch embeddings merged into the token stream
(input_mode=tokens+vision); the decoder applies 3-section M-RoPE over
(temporal, height, width) position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    input_mode="tokens+vision",
    vision_tokens=256,
    source="Qwen2-VL [arXiv:2409.12191]",
)
