"""Model / shape configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the unified
decoder in ``repro.models.transformer`` consumes it.  The four assigned input
shapes are ``ShapeConfig`` instances in ``SHAPES``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    top_k: int = 1
    n_shared_experts: int = 0       # always-on shared experts (DeepSeek-MoE)
    expert_d_ff: int = 0            # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    router_jitter: float = 0.0
    # which layers are MoE: layer i is MoE iff i >= start and (i - start) % every == 0
    moe_start_layer: int = 0
    moe_every: int = 1


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: int = 0             # 0 -> = n_heads (MHA)
    head_dim: int = 0               # 0 -> d_model // n_heads

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0         # 0 -> full causal attention
    attn_logit_softcap: float = 0.0

    # mixer layout: 'attn' | 'rwkv6' | 'mamba'; hybrids interleave.
    mixer: str = "attn"
    attn_every: int = 1             # hybrid: layer i is attention iff (i+1) % attn_every == 0
                                    # (Jamba: attn_every=8 -> layers 7,15,23,31)

    # position information
    rope: str = "rope"              # 'rope' | 'mrope' | 'none'
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    pos_embed: str = "none"         # 'none' | 'sinusoidal' (musicgen)

    # FFN
    act: str = "silu"
    glu: bool = True                # SwiGLU-style gated FFN
    moe: MoEConfig = dataclasses.field(default_factory=MoEConfig)
    dense_d_ff: int = 0             # d_ff for the dense (non-MoE) layers, 0 -> d_ff

    # norm / embeddings
    norm: str = "rmsnorm"           # 'rmsnorm' | 'layernorm'
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # SSM blocks
    mamba: MambaConfig = dataclasses.field(default_factory=MambaConfig)
    rwkv_head_dim: int = 64

    # modality frontend: 'tokens' | 'embeddings' (audio: precomputed frame
    # embeddings) | 'tokens+vision' (VLM: token ids + precomputed patch embeds)
    input_mode: str = "tokens"
    vision_tokens: int = 0          # VLM: number of patch embeddings per example

    source: str = ""                # provenance citation

    # ---- derived ----
    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def layer_kind(self, i: int) -> str:
        """Mixer kind for layer i."""
        if self.mixer == "attn":
            return "attn"
        if self.mixer in ("rwkv6", "mamba") and self.attn_every <= 1:
            return self.mixer
        # hybrid: every `attn_every`-th layer (1-indexed) is attention
        return "attn" if (i + 1) % self.attn_every == 0 else self.mixer

    def is_moe_layer(self, i: int) -> bool:
        m = self.moe
        if m.n_experts == 0 or i < m.moe_start_layer:
            return False
        return (i - m.moe_start_layer) % m.moe_every == 0

    def param_count(self) -> int:
        """Exact parameter count (embeddings included once if tied)."""
        d, hd = self.d_model, self.head_dim_
        total = self.vocab_size * d            # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d       # lm head
        total += d                             # final norm
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            total += d                          # pre-mixer norm
            if kind == "attn":
                q = d * self.n_heads * hd
                kv = 2 * d * self.kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.kv_heads) * hd
                if self.qk_norm:
                    total += 2 * hd
            elif kind == "rwkv6":
                # r,k,v,g,o projections + decay/mix params (approx faithful)
                total += 5 * d * d + 8 * d + 2 * (d // 16) * d + self.rwkv_heads * self.rwkv_head_dim
            elif kind == "mamba":
                di = self.mamba.expand * d
                dtr = self.mamba.dt_rank or -(-d // 16)
                total += d * 2 * di                      # in_proj
                total += di * self.mamba.d_conv + di     # conv
                total += di * (dtr + 2 * self.mamba.d_state)  # x_proj
                total += dtr * di + di                   # dt_proj
                total += di * self.mamba.d_state + di    # A_log, D
                total += di * d                          # out_proj
            # FFN
            total += d                          # pre-ffn norm
            mult = 3 if self.glu else 2
            if self.is_moe_layer(i):
                m = self.moe
                total += m.n_experts * mult * d * m.expert_d_ff
                total += m.n_shared_experts * mult * d * m.expert_d_ff
                total += d * m.n_experts        # router
            else:
                dff = self.dense_d_ff or self.d_ff
                if kind == "rwkv6":
                    total += 2 * d * dff + 2 * d  # rwkv channel-mix (r, k, v=dff)
                else:
                    total += mult * d * dff
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: only top-k + shared experts)."""
        if self.moe.n_experts == 0:
            return self.param_count()
        m = self.moe
        mult = 3 if self.glu else 2
        inactive_experts = m.n_experts - m.top_k
        n_moe_layers = sum(self.is_moe_layer(i) for i in range(self.n_layers))
        return self.param_count() - n_moe_layers * inactive_experts * mult * self.d_model * m.expert_d_ff


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, n_layers: int = 2, d_model: int = 256,
            max_experts: int = 4) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    n_heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.kv_heads, n_heads))
    while n_heads % kv:
        kv -= 1
    head_dim = d_model // n_heads
    moe = cfg.moe
    if moe.n_experts:
        moe = dataclasses.replace(
            moe, n_experts=min(moe.n_experts, max_experts),
            top_k=min(moe.top_k, 2), expert_d_ff=d_model * 2,
            moe_start_layer=min(moe.moe_start_layer, 1), moe_every=1)
    attn_every = cfg.attn_every
    if attn_every > 1:
        attn_every = 2      # hybrid smoke keeps >=1 of each mixer kind
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=kv,
        head_dim=head_dim, d_ff=d_model * 3, dense_d_ff=0,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        attn_every=attn_every,
        moe=moe,
        mrope_sections=(head_dim // 2 - 2 * (head_dim // 6), head_dim // 6, head_dim // 6)
        if cfg.rope == "mrope" else cfg.mrope_sections,
        rwkv_head_dim=min(cfg.rwkv_head_dim, d_model // 2),
        vision_tokens=min(cfg.vision_tokens, 16),
    )
