"""DBRX 132B — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base] 40L d_model=6144 48H kv=8 expert_d_ff=10752
vocab=100352, every layer MoE.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4, expert_d_ff=10752, aux_loss_coef=0.01),
    source="DBRX [hf:databricks/dbrx-base]",
)
