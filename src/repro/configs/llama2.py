"""Llama-2 family — the paper's own experimental models (Touvron et al. 2023).

The paper trains Llama-2 {1B, 7B, 13B, 70B} at context 4096, vocab 32K
(Section 3, 4.5).  These configs drive the paper-figure benchmarks.
"""
from repro.configs.base import ModelConfig


def _llama(name, n_layers, d_model, n_heads, n_kv, d_ff):
    return ModelConfig(
        name=name, family="dense", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_kv, d_ff=d_ff, vocab_size=32000,
        source="Llama 2 [arXiv:2307.09288]")


LLAMA2_1B = _llama("llama2-1b", 16, 2048, 16, 16, 5504)
LLAMA2_7B = _llama("llama2-7b", 32, 4096, 32, 32, 11008)
LLAMA2_13B = _llama("llama2-13b", 40, 5120, 40, 40, 13824)
LLAMA2_70B = _llama("llama2-70b", 80, 8192, 64, 8, 28672)

CONFIGS = {c.name: c for c in (LLAMA2_1B, LLAMA2_7B, LLAMA2_13B, LLAMA2_70B)}
