"""Qwen3-0.6B — dense with per-head q/k RMSNorm (qk_norm) and GQA.

[hf:Qwen/Qwen3-8B family] 28L d_model=1024 16H kv=8 d_ff=3072 vocab=151936,
head_dim=128 (decoupled from d_model/n_heads, as in Qwen3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="Qwen3 [hf:Qwen/Qwen3-8B]",
)
