"""MusicGen-medium — decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284] 48L d_model=1536 24H (MHA) d_ff=6144 vocab=2048.
The EnCodec tokenizer / mel frontend is STUBBED per the brief:
``input_specs`` provides precomputed frame embeddings (input_mode=embeddings).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    rope="none",
    pos_embed="sinusoidal",
    glu=False,
    act="gelu",
    norm="layernorm",
    input_mode="embeddings",
    source="MusicGen [arXiv:2306.05284]",
)
