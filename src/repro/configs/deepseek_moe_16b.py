"""DeepSeek-MoE 16B — fine-grained experts, 2 shared + 64 routed top-6.

[arXiv:2401.06066] 28L d_model=2048 16H (kv=16) expert_d_ff=1408 vocab=102400.
Layer 0 keeps a dense FFN (d_ff=10944) as in the released model.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    dense_d_ff=10944,
    vocab_size=102400,
    moe=MoEConfig(
        n_experts=64, top_k=6, n_shared_experts=2, expert_d_ff=1408,
        moe_start_layer=1, moe_every=1, aux_loss_coef=0.001),
    source="DeepSeekMoE [arXiv:2401.06066]",
)
