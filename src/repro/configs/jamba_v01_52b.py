"""Jamba v0.1 52B — hybrid Mamba + attention (1:7) with MoE every other layer.

[arXiv:2403.19887] 32L d_model=4096 32H kv=8 d_ff=14336 vocab=65536,
MoE 16 experts top-2 on every other layer; attention on layers 8,16,24,32
(1 attention : 7 mamba).
"""
from repro.configs.base import ModelConfig, MoEConfig, MambaConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    mixer="mamba",
    attn_every=8,
    rope="none",               # Jamba uses no positional encoding in attn layers
    moe=MoEConfig(
        n_experts=16, top_k=2, expert_d_ff=14336,
        moe_start_layer=1, moe_every=2, aux_loss_coef=0.01),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    source="Jamba [arXiv:2403.19887]",
)
