"""XLA host-device-count bootstrap.

One shared primitive for every entry point that fakes a multi-device CPU
host (tests/conftest.py, launch/train.py, launch/dryrun.py,
benchmarks/run.py).  Import is jax-free; the call must happen before the
first jax backend initialization to have any effect.
"""
from __future__ import annotations

import os

_FLAG = "xla_force_host_platform_device_count"


def force_host_device_count(n: int, override: bool = False) -> None:
    """Append ``--xla_force_host_platform_device_count=<n>`` to XLA_FLAGS.

    ``override=False`` respects a count already present in the
    environment (e.g. CI's global setting); ``override=True`` appends
    regardless — XLA honors the last occurrence of the flag, so the
    appended value wins.  No-op on real accelerators.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if _FLAG in flags and not override:
        return
    os.environ["XLA_FLAGS"] = (flags + f" --{_FLAG}={n}").strip()
