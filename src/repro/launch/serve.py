"""Serving driver: batched generation with prefill + KV-cache decode.

``python -m repro.launch.serve --arch qwen3-0.6b --reduced --n_new 32``

``--strategy`` routes through the unified strategy API: 'auto' asks the
planner (decode shape, throughput objective), a spec string such as
``fsdp_tp2`` lowers directly, and '' (default) keeps the single-device
path.  Sharded serving places params per the plan and wires the Runtime's
activation constraints, exactly like the dry-run's decode lowering.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import strategy as strategy_lib
from repro.configs import ShapeConfig, get_config, reduced
from repro.core import parallel as par
from repro.models import Runtime, init_params
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--n_new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--strategy", default="",
                    help="'' = single-device; 'auto' = planner; else a spec "
                         "string like fsdp_tp2")
    ap.add_argument("--topology", default="host",
                    help="host | pod | multipod[<k>]")
    ap.add_argument("--kernels", default="jnp", choices=["jnp", "pallas"],
                    help="attention/norm impl; with 'pallas' the paged "
                         "engine's decode segments run the flash-decode "
                         "kernel over the block pool")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "paged", "static"],
                    help="auto routes through the paged continuous-batching "
                         "path when it applies; static forces the dense-"
                         "cache per-token loop")
    ap.add_argument("--n_slots", type=int, default=8,
                    help="in-flight batch bound of the paged engine")
    ap.add_argument("--trace", default="",
                    help="write a Chrome-trace/Perfetto JSON of engine "
                         "ticks/prefill/decode spans here")
    ap.add_argument("--metrics_jsonl", default="",
                    help="stream every telemetry event as JSONL here")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    max_len = args.prompt_len + args.n_new
    key = jax.random.PRNGKey(args.seed)

    plan = None
    if args.strategy:
        topo = strategy_lib.get_topology(args.topology)
        shape = ShapeConfig("serve", max_len, args.batch, "decode")
        strat, planned = strategy_lib.resolve(args.strategy, cfg, topo, shape)
        plan = strat.to_plan(cfg, topo, shape)
        print(f"[strategy] {strat.format()} on {topo.name} "
              f"(mesh {dict(plan.mesh.shape)}, attn={plan.attn})")
        # moe_impl / moe_groups come from the resolved plan (make_runtime:
        # 'ep' when the plan has an expert axis, 'dropping' otherwise) —
        # the served model must run the same dispatch the plan shards for
        rt = par.make_runtime(cfg, plan, shape, remat=False,
                              rwkv_chunk=16, mamba_chunk=32,
                              attn_impl=args.kernels, norm_impl=args.kernels)
        params = init_params(cfg, key)
        pshard = par.param_shardings(
            cfg, plan, jax.eval_shape(lambda: params))
        params = jax.device_put(params, pshard)
    else:
        # single-device path: 'auto' picks the dense oracle for small
        # token counts and the dropping dispatch above the threshold
        rt = Runtime(rwkv_chunk=16, mamba_chunk=32, moe_impl="auto",
                     attn_impl=args.kernels, norm_impl=args.kernels)
        params = init_params(cfg, key)
    from repro import telemetry as tel
    recorder = tel.NULL
    if args.trace or args.metrics_jsonl:
        recorder = tel.Recorder()
        if args.metrics_jsonl:
            recorder.add_sink(tel.JsonlSink(args.metrics_jsonl))
        if args.trace:
            recorder.add_sink(tel.ChromeTraceSink(
                args.trace, process_name=f"serve {cfg.name}"))
    engine = ServeEngine(cfg, params, rt, max_len=max_len, plan=plan,
                         seed=args.seed, n_slots=args.n_slots,
                         telemetry=recorder)
    if args.engine == "paged" and not engine.paged_ok:
        raise SystemExit("--engine paged needs a single-device plan and an "
                         "attention-only stack")
    use_paged = engine.paged_ok and args.engine != "static"

    prompts = jax.random.randint(key, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.time()
    if use_paged:
        out = engine.generate(prompts, args.n_new,
                              temperature=args.temperature, key=key)
    else:
        out = engine.generate_static(prompts, args.n_new,
                                     temperature=args.temperature, key=key)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.n_new} engine={'paged' if use_paged else 'static'}")
    print(f"generated {args.batch * args.n_new} tokens in {dt:.2f}s "
          f"({args.batch * args.n_new / dt:.1f} tok/s on "
          f"{jax.default_backend()})")
    print("first sequence tail:", out[0, -min(16, args.n_new):].tolist())
    if recorder is not tel.NULL:
        snap = recorder.metrics.snapshot()
        lat = snap.get("serve/token_latency_s")
        if lat and lat.get("count"):
            print(f"[telemetry] token latency p50 {lat['p50'] * 1e3:.2f}ms "
                  f"p99 {lat['p99'] * 1e3:.2f}ms over {lat['count']} tokens")
        ttft = snap.get("serve/ttft_s")
        if ttft and ttft.get("count"):
            print(f"[telemetry] ttft p50 {ttft['p50'] * 1e3:.2f}ms "
                  f"p99 {ttft['p99'] * 1e3:.2f}ms")
        recorder.close()
        if args.trace:
            print(f"[telemetry] trace written to {args.trace}")
    assert out.shape == (args.batch, args.prompt_len + args.n_new)


if __name__ == "__main__":
    main()
