import os
import sys

from repro.launch.devices import force_host_device_count


def _force_fake_devices(argv):
    """Set the XLA host device count BEFORE the jax import below.

    Pod meshes need 512 fake devices (override=True: the appended flag
    wins over any smaller env default); '--topology host' keeps a small
    live mesh (8, or whatever the environment already set) so compiled
    steps can also be *executed* (e.g. the --measure_bubble pipeline
    probe).  CLI-only: importing this module as a library leaves the
    caller's device count alone.
    """
    topo = ""
    for i, a in enumerate(argv):
        if a == "--topology" and i + 1 < len(argv):
            topo = argv[i + 1]
        elif a.startswith("--topology="):
            topo = a.split("=", 1)[1]
    if topo == "host":
        force_host_device_count(8)
    else:
        force_host_device_count(512, override=True)


if __name__ == "__main__":          # before the jax import below
    _force_fake_devices(sys.argv)

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and record memory / FLOP / collective statistics.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, compile-time OOM, or unsupported collective
fails here.  Results feed EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi_pod]
  python -m repro.launch.dryrun ... --out results/dryrun
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
      --topology host --reduced --strategy fsdp_pp2_mb8 --measure_bubble
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 2.0)

from repro import strategy as strategy_lib
from repro.configs import SHAPES, get_config, list_archs, supports_shape
from repro.core import parallel as par
from repro.launch import specs as specs_lib
from repro.models import transformer as tfm
from repro.optim import init_opt_state
from repro.perf import flops as flops_lib
from repro.perf.hlo import collective_stats
from repro.serve.engine import make_prefill, make_serve_step
from repro.train.trainer import TrainConfig, make_train_step

SDS = jax.ShapeDtypeStruct


def _to_dtype_sds(shapes, shardings, float_dtype):
    def one(s, sh):
        dt = float_dtype if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype
        return SDS(s.shape, dt, sharding=sh)
    return jax.tree.map(one, shapes, shardings)


def _attach(shapes, shardings):
    return jax.tree.map(lambda s, sh: SDS(s.shape, s.dtype, sharding=sh),
                        shapes, shardings)


def resolve_strategy(cfg, shape, topo, strategy: str, dp_mode: str = "hsdp",
                     attn_override=None, seq_parallel: bool = True):
    """Map (--strategy, legacy flags) to a Strategy descriptor.

    '' (default) keeps the paper's pod layout — model axis 16 — with the
    legacy dp_mode/attn/sp flags folded in; 'auto' asks the planner;
    anything else is a spec string (legacy flags still apply on top unless
    the spec sets them itself).
    """
    if strategy == "auto":
        s, _ = strategy_lib.resolve("auto", cfg, topo, shape)
    elif not strategy:
        s = strategy_lib.Strategy(
            dp_mode="fsdp" if dp_mode == "fsdp2d" else "hsdp", tp=16)
    else:
        s = strategy_lib.parse(strategy)
    if attn_override and s.attn is None:
        s = dataclasses.replace(s, attn=attn_override)
    if not seq_parallel:
        s = dataclasses.replace(s, seq_parallel=False)
    if dp_mode == "fsdp2d" and s.dp_mode == "hsdp":
        s = dataclasses.replace(s, dp_mode="fsdp")
    return s


def _topology(name: str, multi_pod: bool):
    """'' keeps the legacy pod/multipod selection; 'host' is a live mesh."""
    if name:
        return strategy_lib.get_topology(name)
    return strategy_lib.pod_topology(pods=2 if multi_pod else 1)


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              dp_mode: str = "hsdp", attn_override=None, rt_overrides=None,
              donate: bool = False, seq_parallel: bool = True,
              grad_accum: int = 1, strategy: str = "",
              topology: str = "", use_reduced: bool = False):
    from repro.configs import reduced
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    shape = SHAPES[shape_name]
    topo = _topology(topology, multi_pod)
    strat = resolve_strategy(cfg, shape, topo, strategy, dp_mode,
                             attn_override, seq_parallel)
    plan = strat.to_plan(cfg, topo, shape)
    mesh = plan.mesh
    rt = par.make_runtime(cfg, plan, shape, **(rt_overrides or {}))

    key = jax.random.PRNGKey(0)
    pshapes = jax.eval_shape(functools.partial(tfm.init_params, cfg), key)
    pshard = par.param_shardings(cfg, plan, pshapes)
    # lower with the storage dtype the strategy's precision policy
    # actually trains with (previously hard-coded bf16 while train_loop
    # ran f32 — the compiled memory/collective stats described a program
    # nothing executed)
    params_sds = _to_dtype_sds(pshapes, pshard, rt.param_dtype)

    with par.use_mesh(mesh):
        if shape.mode == "train":
            batch = specs_lib.train_batch_specs(cfg, shape)
            bshard = par.batch_specs(cfg, plan, batch)
            batch_sds = _attach(batch, bshard)
            oshapes = jax.eval_shape(init_opt_state, params_sds)
            oshard = {"m": pshard, "v": pshard,
                      "step": par.fitted(plan, par.P(), ())}
            opt_sds = _attach(oshapes, oshard)
            # the ga<k> spec token wins unless --grad_accum was set explicitly
            # (train.py applies the same precedence)
            ga = grad_accum if grad_accum > 1 else strat.grad_accum
            step = make_train_step(cfg, rt, TrainConfig(grad_accum=ga))
            lowered = jax.jit(step, out_shardings=(pshard, oshard, None),
                              donate_argnums=(0, 1) if donate else ()) \
                .lower(params_sds, opt_sds, batch_sds)
        elif shape.mode == "prefill":
            batch = specs_lib.prefill_batch_specs(cfg, shape)
            bshard = par.batch_specs(cfg, plan, batch)
            batch_sds = _attach(batch, bshard)
            fn = make_prefill(cfg, rt, max_len=shape.seq_len)
            cshapes = jax.eval_shape(
                lambda: tfm.init_cache(cfg, shape.global_batch, shape.seq_len,
                                       rt.compute_dtype, par.make_runtime(
                                           cfg, plan, shape, constrain=None)))
            cshard = par.cache_shardings(cfg, plan, cshapes)
            lowered = jax.jit(fn, out_shardings=(None, cshard)) \
                .lower(params_sds, batch_sds)
        else:  # decode
            rt_nc = par.make_runtime(cfg, plan, shape, constrain=None)
            cshapes = jax.eval_shape(
                lambda: tfm.init_cache(cfg, shape.global_batch, shape.seq_len,
                                       rt.compute_dtype, rt_nc))
            cshard = par.cache_shardings(cfg, plan, cshapes)
            cache_sds = _attach(cshapes, cshard)
            tokens, pos = specs_lib.decode_token_specs(cfg, shape)
            tok_sds = SDS(tokens.shape, tokens.dtype,
                          sharding=par.fitted(plan, par.P(plan.dp, None),
                                              tokens.shape))
            pos_sds = SDS((), jnp.int32,
                          sharding=par.fitted(plan, par.P(), ()))
            step = make_serve_step(cfg, rt)
            lowered = jax.jit(step, out_shardings=(None, cshard)) \
                .lower(params_sds, cache_sds, tok_sds, pos_sds)
    return cfg, shape, strat, plan, lowered


def run_label(arch: str, shape_name: str, multi_pod: bool,
              strategy: str = "", tag: str = "", topology: str = ""):
    """(mesh_name, label) naming one sweep point — also its artifact path,
    so main()'s skip-if-existing check and run_one()'s writer must agree."""
    if topology:
        mesh_name = topology
    else:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if strategy:
        mesh_name += f"_{strategy}"
    label = f"{arch}_{shape_name}_{mesh_name}" + (f"_{tag}" if tag else "")
    return mesh_name, label


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            dp_mode: str = "hsdp", attn_override=None, tag: str = "",
            rt_overrides=None, donate: bool = False,
            seq_parallel: bool = True, grad_accum: int = 1,
            strategy: str = "", topology: str = "",
            use_reduced: bool = False, measure_bubble: bool = False,
            telemetry=None):
    from repro import telemetry as tel
    telemetry = telemetry if telemetry is not None else tel.NULL
    mesh_name, label = run_label(arch, shape_name, multi_pod, strategy, tag,
                                 topology)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not supports_shape(cfg, shape):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped",
               "reason": "requires sub-quadratic attention (DESIGN.md §4)"}
        _write(out_dir, label, rec)
        print(f"[dryrun] {label}: SKIP (full attention, long context)")
        return rec

    t0 = time.time()
    try:
        from repro.core.expert import dispatch_stats_snapshot
        stats0 = dispatch_stats_snapshot()
        with telemetry.span("dryrun/lower", label=label):
            cfg, shape, strat, plan, lowered = lower_one(
                arch, shape_name, multi_pod, dp_mode, attn_override,
                rt_overrides, donate, seq_parallel, grad_accum, strategy,
                topology, use_reduced)
        t_lower = time.time() - t0
        t0 = time.time()
        with telemetry.span("dryrun/compile", label=label):
            compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # older jax returns [dict]
            cost = cost[0] if cost else {}
        # trip-count-scaled: while bodies multiplied by known_trip_count
        coll = collective_stats(compiled.as_text())
        n_dev = plan.mesh.devices.size          # chips in THIS mesh
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "ok", "strategy": strat.format(),
            "strategy_arg": strategy or "legacy-default",
            "precision": strat.precision,
            "plan": {
                "attn": plan.attn, "kv_tp": plan.kv_tp, "dp": list(plan.dp),
                "fsdp": list(plan.fsdp), "expert": plan.expert,
                "mesh": {k: int(v) for k, v in plan.mesh.shape.items()},
                "decode_cache_axes": list(plan.decode_cache_axes)},
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "n_devices": n_dev,
            "flops_hlo_per_device_raw": cost.get("flops", 0.0),
            "bytes_accessed_per_device_raw": cost.get("bytes accessed", 0.0),
            "flops_compiled_analytic": flops_lib.compiled_flops(
                cfg, shape, remat=(shape.mode == "train")),
            "flops_forward_analytic": flops_lib.forward_flops(cfg, shape),
            "flops_model_6nd": flops_lib.model_flops(cfg, shape),
            "memory": {
                "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes_per_device": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
            },
            "collectives": coll,
            "collective_bytes_total": int(sum(v["bytes"] for v in coll.values())),
            "params_total": cfg.param_count(),
            "params_active": cfg.active_param_count(),
            "rt_overrides": {k: bool(v) if isinstance(v, bool) else v
                             for k, v in (rt_overrides or {}).items()
                             if not callable(v)},
            "donate": donate,
        }
        # resilience prediction: what the goodput model says failures cost
        # this exact (strategy, topology) point — system MTBF, the
        # strategy-aware checkpoint write time (distinct-writer
        # parallelism), the Young/Daly interval, and the effective
        # throughput fraction left after checkpoint stalls + lost work +
        # restarts
        from repro.core import costmodel as cm
        topo_res = _topology(topology, multi_pod)
        cost_strat = strat.to_cost_strategy(cfg, topo_res)
        hw = topo_res.hw
        t_ck = cm.checkpoint_write_time(cfg, hw, cost_strat)
        mtbf_sys = cm.system_mtbf(hw, cost_strat.n_devices)
        g = cm.goodput(t_ck, mtbf_sys,
                       t_restart=cm.restart_time(cfg, hw, cost_strat))
        rec["resilience"] = {
            "mtbf_device_s": hw.mtbf,
            "mtbf_system_s": round(mtbf_sys, 1),
            "ckpt_bytes": cm.checkpoint_bytes(cfg),
            "distinct_writers": cm.distinct_writers(cost_strat),
            "t_ckpt_s": round(t_ck, 4),
            "young_daly_interval_s": round(
                cm.young_daly_interval(t_ck, mtbf_sys), 1),
            "goodput": round(g, 5),
        }
        if cfg.moe.n_experts:
            # which EP entry this lowering's apply_moe calls actually took
            # (trace-time deltas): 'ep_padded_calls' means small token
            # counts ran the padded all-to-all, 'ep_fallback_calls' means
            # the plan's dispatch was NOT what lowered (GSPMD dropping)
            stats1 = dispatch_stats_snapshot()
            rec["moe_dispatch"] = {k: stats1[k] - stats0[k] for k in stats1}
        if strat.pp > 1:
            # pipeline section: the analytic per-schedule bubble and
            # in-flight activation count, plus (on a live host mesh with
            # --measure_bubble) the executed bubble, so the cost model's
            # schedule terms are validated, not assumed
            from repro.core.pipeline import (bubble_fraction,
                                             inflight_microbatches,
                                             op_tick_counts,
                                             virtual_stages)
            rec["pipeline"] = {
                "pp": strat.pp, "microbatches": strat.microbatches,
                "sched": strat.sched,
                "virtual_stages": virtual_stages(strat.sched),
                "overlap": strat.overlap,
                "bubble_predicted": bubble_fraction(
                    strat.pp, strat.microbatches, strat.sched),
                "inflight_microbatches": inflight_microbatches(
                    strat.pp, strat.microbatches, strat.sched),
                # sub-tick census of the executed table (zb splits each
                # backward into dgrad 'B' + wgrad 'W' sub-ticks)
                "op_tick_counts": op_tick_counts(
                    strat.sched, strat.pp, strat.microbatches),
            }
            # the probe only means something on a live host mesh: on a
            # pod topology the 512 CPU-emulated fake devices would
            # "measure" emulation overhead, not the schedule
            topo_obj = _topology(topology, multi_pod)
            if measure_bubble and topology == "host" and \
                    topo_obj.n_devices <= len(jax.devices()):
                from repro.configs import reduced
                from repro.perf.pipeline_probe import measure_bubble as _probe
                # layer count must split into pp x v virtual-stage chunks
                chunk = strat.pp * virtual_stages(strat.sched)
                n_l = -(-max(4, 2 * strat.pp) // chunk) * chunk
                probe_cfg = reduced(get_config(arch), n_layers=n_l)
                rec["pipeline"].update(_probe(probe_cfg, strat, topo_obj))
        print(f"[dryrun] {label}: OK  compile {t_compile:.0f}s  "
              f"flops {rec['flops_compiled_analytic']:.3e}  "
              f"coll {rec['collective_bytes_total']:.3e}B  "
              f"temp/dev {rec['memory']['temp_bytes_per_device']/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": repr(e),
               "traceback": traceback.format_exc()[-4000:]}
        print(f"[dryrun] {label}: FAIL {e!r}")
    _write(out_dir, label, rec)
    return rec


def _write(out_dir, label, rec):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, label + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--both_meshes", action="store_true")
    ap.add_argument("--topology", default="",
                    help="'' = pod/multipod (512 fake devices); 'host' = "
                         "small live mesh (compiled steps can execute, "
                         "e.g. --measure_bubble)")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of each arch")
    ap.add_argument("--measure_bubble", action="store_true",
                    help="for pp>1 strategies on a live topology, execute "
                         "the GPipe schedule and record the measured "
                         "bubble fraction next to the prediction")
    ap.add_argument("--strategy", default="",
                    help="'' = legacy pod layout (model axis 16), 'auto' = "
                         "planner, else a spec string like hsdp_tp4 / "
                         "fsdp_cp8")
    ap.add_argument("--dp_mode", default="hsdp", choices=["hsdp", "fsdp2d"])
    ap.add_argument("--attn", default=None, choices=[None, "head_tp", "context"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip_existing", action="store_true")
    # perf-iteration knobs (§Perf): each maps to a Runtime override
    ap.add_argument("--donate", action="store_true",
                    help="donate params+opt buffers to the step")
    ap.add_argument("--remat_inner", action="store_true",
                    help="checkpoint each layer inside scanned blocks")
    ap.add_argument("--gather_per_block", action="store_true",
                    help="force per-layer FSDP all-gather inside the scan")
    ap.add_argument("--mamba_chunk", type=int, default=0)
    ap.add_argument("--rwkv_chunk", type=int, default=0)
    ap.add_argument("--attn_kv_chunk", type=int, default=0)
    ap.add_argument("--attn_q_chunk", type=int, default=0)
    ap.add_argument("--no_sp", action="store_true",
                    help="disable sequence-parallel residual stream")
    ap.add_argument("--grad_accum", type=int, default=1)
    ap.add_argument("--kernels", default="", choices=["", "jnp", "pallas"],
                    help="attention/norm impl override ('' keeps Runtime "
                         "defaults)")
    ap.add_argument("--trace", default="",
                    help="write per-config lower/compile spans as a "
                         "Chrome-trace/Perfetto JSON here")
    args = ap.parse_args()
    rt_overrides = {}
    if args.kernels:
        rt_overrides["attn_impl"] = args.kernels
        rt_overrides["norm_impl"] = args.kernels
    if args.remat_inner:
        rt_overrides["remat_inner"] = True
    if args.gather_per_block:
        rt_overrides["fsdp_gather_per_block"] = True
    for k in ("mamba_chunk", "rwkv_chunk", "attn_kv_chunk", "attn_q_chunk"):
        if getattr(args, k):
            rt_overrides[k] = getattr(args, k)

    archs = list_archs(assigned_only=True) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    if args.topology:
        # an explicit topology overrides the pod/multipod pair entirely —
        # looping both meshes would run the identical config twice
        meshes = [False]
    elif args.both_meshes:
        meshes = [False, True]
    else:
        meshes = [args.multi_pod]

    from repro import telemetry as tel
    recorder = tel.NULL
    if args.trace:
        recorder = tel.Recorder()
        recorder.add_sink(tel.ChromeTraceSink(args.trace,
                                              process_name="dryrun"))

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                _, label = run_label(arch, shape, mp, args.strategy,
                                     args.tag, args.topology)
                path = os.path.join(args.out, label + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[dryrun] {label}: cached")
                            continue
                rec = run_one(arch, shape, mp, args.out, args.dp_mode,
                              args.attn, args.tag, rt_overrides, args.donate,
                              not args.no_sp, args.grad_accum, args.strategy,
                              args.topology, args.reduced,
                              args.measure_bubble, telemetry=recorder)
                n_fail += rec["status"] == "error"
    recorder.close()
    if args.trace:
        print(f"[telemetry] trace written to {args.trace}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
