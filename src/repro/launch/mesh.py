"""Production mesh builders.

TPU v5e target: one pod = 256 chips as a (16, 16) (data, model) mesh;
multi-pod = 2 pods = 512 chips with a leading 'pod' axis (DCN-connected).
Functions, not module constants: importing this module must never touch
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (fake) devices are present (tests)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
