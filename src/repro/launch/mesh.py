"""Mesh builders, parameterized by topology.

The supported path is ``repro.strategy``: a ``Topology`` names the cluster
and ``Strategy.to_plan`` builds the mesh from it (``strategy.build_mesh``
underneath) — no hard-coded shapes.  The two legacy entry points below are
thin shims over that path, kept for callers that predate the strategy API.
Functions, not module constants: importing this module must never touch
jax device state.
"""
from __future__ import annotations

from repro.strategy.topology import (Topology, build_mesh, get_topology,
                                     host_topology, pod_topology)

__all__ = ["Topology", "build_mesh", "get_topology", "host_topology",
           "pod_topology", "make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False, model: int = 16):
    """Deprecated shim — the TPU v5e target via the topology API.

    One pod = 256 chips as (data, model); multi-pod adds a leading 'pod'
    axis (DCN-connected).  Equivalent to
    ``build_mesh(pod_topology(pods), model=16, pods=pods)``.
    """
    pods = 2 if multi_pod else 1
    return build_mesh(pod_topology(pods=pods), model=model,
                      pods=pods if multi_pod else 1)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Deprecated shim — small mesh over local (fake) devices (tests).

    Contract kept from the pre-strategy API: any ``pod >= 1`` adds a
    leading 'pod' axis (even of size 1), ``pod=0`` omits it.
    """
    if pod:
        import jax
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    topo = host_topology(n_devices=data * model)
    return build_mesh(topo, model=model)
