"""Training driver: ``python -m repro.launch.train --arch qwen3-0.6b ...``

Runs real training on whatever devices exist (CPU here; the same code path
lowers for the production TPU mesh — the mesh shape is the only delta).
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, reduced, ShapeConfig
from repro.core import parallel as par
from repro.data import Batcher, BinTokenSource, SyntheticSource
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import AdamWConfig
from repro.train.trainer import TrainConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq_len", type=int, default=512)
    ap.add_argument("--global_batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad_accum", type=int, default=1)
    ap.add_argument("--data", default="synthetic",
                    help="'synthetic' or a path to a flat uint16 token file")
    ap.add_argument("--ckpt_dir", default="")
    ap.add_argument("--ckpt_every", type=int, default=0)
    ap.add_argument("--log_every", type=int, default=10)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod", "multipod"],
                    help="'host' = all local devices as (data,); 'pod'/"
                         "'multipod' = production meshes (needs real chips)")
    ap.add_argument("--dp_mode", default="hsdp", choices=["hsdp", "fsdp2d"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    if args.mesh == "host":
        mesh = make_host_mesh(data=len(jax.devices()), model=1)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    plan = par.choose_plan(cfg, mesh, shape, dp_mode=args.dp_mode)
    rt = par.make_runtime(cfg, plan, shape,
                          param_dtype=jnp.float32, compute_dtype=jnp.float32,
                          remat=False, rwkv_chunk=32, mamba_chunk=64,
                          attn_min_chunked_len=max(2048, args.seq_len + 1)
                          if args.seq_len <= 2048 else 2048)

    if args.data == "synthetic":
        src = SyntheticSource(cfg.vocab_size, seed=args.seed)
    else:
        src = BinTokenSource(args.data)
    batches = Batcher(src, args.seq_len, args.global_batch)

    tc = TrainConfig(steps=args.steps, warmup=max(args.steps // 20, 1),
                     log_every=args.log_every, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir or os.path.join("results", "ckpt",
                                                            cfg.name),
                     grad_accum=args.grad_accum,
                     opt=AdamWConfig(lr=args.lr))
    params, opt_state, history = train_loop(
        cfg, plan, rt, tc, batches, key=jax.random.PRNGKey(args.seed))
    losses = [h["loss"] for h in history]
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"over {args.steps} steps")
    return history


if __name__ == "__main__":
    main()
