"""Training driver: ``python -m repro.launch.train --arch qwen3-0.6b ...``

Runs real training on whatever devices exist (CPU here; the same code path
lowers for the production TPU mesh — the topology is the only delta).

Strategy selection goes through the unified API (``repro.strategy``):

  --strategy auto        planner picks the best executable strategy for
                         (arch, topology, batch) with the calibrated cost
                         model (throughput objective by default)
  --strategy hsdp_tp4    explicit spec string, lowered directly

On a CPU host, ``--host_devices`` (default 8) forces that many fake XLA
host devices so multi-axis strategies exercise the real SPMD path; it is a
no-op on real accelerators.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.launch.devices import force_host_device_count


def _force_host_devices(argv):
    """Set XLA host device count BEFORE jax import (CPU-only effect)."""
    n = "8"
    for i, a in enumerate(argv):
        if a == "--host_devices" and i + 1 < len(argv):
            n = argv[i + 1]
        elif a.startswith("--host_devices="):
            n = a.split("=", 1)[1]
    try:
        count = int(n)
    except ValueError:
        return                    # let argparse report the bad value
    if count > 0:
        force_host_device_count(count)


if __name__ == "__main__":          # before jax import below
    _force_host_devices(sys.argv)

import jax
import jax.numpy as jnp

from repro import strategy as strategy_lib
from repro.configs import ShapeConfig, get_config, reduced
from repro.core import parallel as par
from repro.data import Batcher, BinTokenSource, SyntheticSource
from repro.optim import AdamWConfig
from repro.train.trainer import TrainConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq_len", type=int, default=512)
    ap.add_argument("--global_batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad_accum", type=int, default=0,
                    help="0 -> take it from the strategy spec (ga<k>)")
    ap.add_argument("--data", default="synthetic",
                    help="'synthetic' or a path to a flat uint16 token file")
    ap.add_argument("--ckpt_dir", default="")
    ap.add_argument("--ckpt_every", type=int, default=0)
    ap.add_argument("--log_every", type=int, default=10)
    ap.add_argument("--topology", "--mesh", dest="topology", default="host",
                    help="host | pod | multipod[<k>] (pod meshes need real "
                         "chips)")
    ap.add_argument("--strategy", default="auto",
                    help="'auto' (planner) or a spec string like hsdp_tp4 / "
                         "fsdp_cp2 / fsdp_pp2_mb8_1f1b / fsdp_pp2_ep2_mb2 / "
                         "ddp")
    ap.add_argument("--objective", default="wps",
                    choices=sorted(strategy_lib.OBJECTIVES))
    ap.add_argument("--host_devices", type=int, default=8,
                    help="fake XLA host devices on CPU (0 = leave alone)")
    ap.add_argument("--kernels", default="jnp", choices=["jnp", "pallas"],
                    help="attention/norm impl: 'pallas' runs the fwd+bwd "
                         "Pallas kernels (interpret mode off-TPU)")
    ap.add_argument("--seed", type=int, default=0)
    # resilience: supervised restarts, fault injection, async checkpointing
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest valid checkpoint in ckpt_dir")
    ap.add_argument("--async_ckpt", action="store_true",
                    help="snapshot on-thread, write checkpoints in background")
    ap.add_argument("--ckpt_keep", type=int, default=0,
                    help="gc all but the newest N checkpoints (0 = keep all)")
    ap.add_argument("--max_restarts", type=int, default=0,
                    help="supervise the run: restart up to N times on "
                         "failure, restoring from the latest valid ckpt")
    ap.add_argument("--fault_plan", default="",
                    help="inject faults: 'crash@<step>[,..]' or a FaultPlan "
                         "JSON path")
    ap.add_argument("--event_log", default="",
                    help="write the supervisor's structured event log here")
    # observability: spans + metrics to pluggable sinks (see README
    # "Observability"); all three default off and cost nothing when off
    ap.add_argument("--trace", default="",
                    help="write a Chrome-trace/Perfetto JSON of the run's "
                         "spans here (open at ui.perfetto.dev)")
    ap.add_argument("--metrics_jsonl", default="",
                    help="stream every telemetry event (spans, counters, "
                         "gauges, histograms) as JSONL here")
    ap.add_argument("--drift_report", default="",
                    help="write per-window predicted-vs-measured step-time "
                         "drift (cost model vs telemetry spans) here")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    topo = strategy_lib.get_topology(args.topology)
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    strat, planned = strategy_lib.resolve(args.strategy, cfg, topo, shape,
                                          objective=args.objective)
    plan = strat.to_plan(cfg, topo, shape)
    if planned is not None:
        r = planned.report
        print(f"[planner] chose {strat.format()} on {topo.name} "
              f"({topo.n_devices}x {topo.hardware}): predicted "
              f"{r.wps:,.0f} tok/s, mfu {r.mfu:.3f}, "
              f"{r.memory_per_device / 2**30:.2f} GiB/dev")
    else:
        print(f"[strategy] {strat.format()} on {topo.name} "
              f"(mesh {dict(plan.mesh.shape)})")

    # dtypes come from the strategy's precision policy (plan.policy): the
    # default/_f32 spec keeps pure f32, a _bf16 spec trains bf16 with f32
    # master params, _fp8 additionally quantizes the ZeRO gather wire
    rt = par.make_runtime(cfg, plan, shape,
                          remat=False, rwkv_chunk=32, mamba_chunk=64,
                          attn_impl=args.kernels, norm_impl=args.kernels,
                          attn_min_chunked_len=max(2048, args.seq_len + 1)
                          if args.seq_len <= 2048 else 2048)

    def make_batches():
        # fresh per attempt: sources are stateful; a resumed attempt
        # replays the stream and skips to the restored position
        if args.data == "synthetic":
            src = SyntheticSource(cfg.vocab_size, seed=args.seed)
        else:
            src = BinTokenSource(args.data)
        return Batcher(src, args.seq_len, args.global_batch)

    grad_accum = args.grad_accum or strat.grad_accum
    tc = TrainConfig(steps=args.steps, warmup=max(args.steps // 20, 1),
                     log_every=args.log_every, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir or os.path.join("results", "ckpt",
                                                            cfg.name),
                     grad_accum=grad_accum,
                     opt=AdamWConfig(lr=args.lr),
                     ckpt_async=args.async_ckpt, ckpt_keep=args.ckpt_keep,
                     resume=args.resume)

    fault_plan = None
    if args.fault_plan:
        from repro.resilience import load_fault_plan
        fault_plan = load_fault_plan(args.fault_plan)

    from repro import telemetry as tel
    recorder = tel.NULL
    if args.trace or args.metrics_jsonl or args.drift_report:
        recorder = tel.Recorder()
        if args.metrics_jsonl:
            recorder.add_sink(tel.JsonlSink(args.metrics_jsonl))
        if args.trace:
            recorder.add_sink(tel.ChromeTraceSink(
                args.trace, process_name=f"train {cfg.name}"))
    drift = None
    if args.drift_report:
        # predicted side: the cost model's decomposition for the resolved
        # strategy; measured side arrives from train_loop's log windows
        report = planned.report if planned is not None else \
            strategy_lib.evaluate(cfg, strat, topo, shape)
        hw = topo.hw
        drift = tel.DriftMonitor(
            report.decomposition(), telemetry=recorder,
            meta={"spec": strat.format(), "topology": topo.name,
                  "hardware": topo.hardware, "arch": cfg.name,
                  "seq_len": args.seq_len,
                  "global_batch": args.global_batch,
                  # invert mfu = model_flops / (t_step * n * peak) so the
                  # trainer can gauge measured MFU without re-deriving
                  "model_flops_per_step":
                      report.mfu * report.t_step
                      * topo.n_devices * hw.flops_bf16,
                  "cluster_peak_flops":
                      topo.n_devices * hw.flops_bf16})

    if args.max_restarts > 0:
        from repro.resilience.supervisor import (SupervisorConfig,
                                                 supervise_training)
        rt_overrides = dict(
            remat=False, rwkv_chunk=32, mamba_chunk=64,
            attn_impl=args.kernels, norm_impl=args.kernels,
            attn_min_chunked_len=max(2048, args.seq_len + 1)
            if args.seq_len <= 2048 else 2048)
        params, opt_state, history, sup = supervise_training(
            cfg, strat, topo, shape, tc, make_batches,
            rt_overrides=rt_overrides, key=jax.random.PRNGKey(args.seed),
            fault_plan=fault_plan,
            sup_cfg=SupervisorConfig(max_restarts=args.max_restarts,
                                     event_log_path=args.event_log),
            telemetry=recorder, drift=drift)
        n_failures = sum(e["kind"] == "failure" for e in sup.events)
        if n_failures:
            print(f"[supervisor] recovered from {n_failures} failure(s)"
                  + (f"; event log: {args.event_log}" if args.event_log
                     else ""))
    else:
        params, opt_state, history = train_loop(
            cfg, plan, rt, tc, make_batches(),
            key=jax.random.PRNGKey(args.seed), fault_plan=fault_plan,
            telemetry=recorder, drift=drift)
    recorder.close()
    if args.trace:
        print(f"[telemetry] trace written to {args.trace}")
    if args.drift_report and drift is not None:
        drift.write(args.drift_report)
        mean = drift.summary()["mean_predicted_over_measured"]
        terms = ", ".join(f"{t}={r:.3g}" for t, r in mean.items())
        print(f"[telemetry] drift report -> {args.drift_report}"
              + (f" (predicted/measured: {terms})" if terms else ""))
    losses = [h["loss"] for h in history]
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"over {args.steps} steps")
    return history


if __name__ == "__main__":
    main()
