"""ShapeDtypeStruct stand-ins for every model input: the dry-run's inputs.

``input_specs(cfg, shape)`` returns the abstract batch for train/prefill or
the (tokens, pos) pair for decode; modality frontends (audio codec / vision
tower) are stubbed as precomputed embeddings of the right shape, per the
brief.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                      dtype=jnp.bfloat16) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = SDS((B, S, cfg.d_model), dtype)
    else:
        batch["tokens"] = SDS((B, S), jnp.int32)
    batch["labels"] = SDS((B, S), jnp.int32)
    if cfg.input_mode == "tokens+vision":
        batch["vision_embeds"] = SDS((B, cfg.vision_tokens, cfg.d_model), dtype)
        batch["position_ids"] = SDS((3, B, S), jnp.int32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                        dtype=jnp.bfloat16) -> Dict:
    batch = train_batch_specs(cfg, shape, dtype)
    batch.pop("labels")
    return batch


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    B = shape.global_batch
    tokens = SDS((B, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return tokens, pos


def concrete_train_batch(cfg: ModelConfig, batch_size: int, seq_len: int,
                         key, dtype=jnp.float32) -> Dict:
    """Small concrete batch for smoke tests (same structure as the specs)."""
    ks = jax.random.split(key, 4)
    batch = {}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.random.normal(
            ks[0], (batch_size, seq_len, cfg.d_model), dtype) * 0.1
    else:
        batch["tokens"] = jax.random.randint(
            ks[0], (batch_size, seq_len), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(
        ks[1], (batch_size, seq_len), 0, cfg.vocab_size)
    if cfg.input_mode == "tokens+vision":
        batch["vision_embeds"] = jax.random.normal(
            ks[2], (batch_size, cfg.vision_tokens, cfg.d_model), dtype) * 0.02
        pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32)[None],
                               (batch_size, seq_len))
        batch["position_ids"] = jnp.broadcast_to(pos[None], (3, batch_size, seq_len))
    return batch
