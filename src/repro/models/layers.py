"""Shared building blocks: norms, positional encodings, FFNs, embeddings.

Everything is a pure function over explicit parameter pytrees (dicts of
jnp arrays); initialization lives next to the apply function.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# runtime knobs (orthogonal to ModelConfig: numerics / impl selection)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Runtime:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    grad_dtype: jnp.dtype = jnp.float32  # grad-accumulation/reduce dtype
                                         # (mixed-precision policy; the
                                         # optimizer still updates in f32)
    remat: bool = False                 # checkpoint each scanned layer-block
    attn_q_chunk: int = 1024            # query chunk for blocked attention
    attn_kv_chunk: int = 1024           # kv chunk for blocked attention
    attn_min_chunked_len: int = 2048    # below this, plain masked attention
    rwkv_chunk: int = 64
    mamba_chunk: int = 256
    moe_impl: str = "auto"              # 'dense' | 'dropping' | 'ep' | 'auto'
    moe_groups: int = 1                 # data shards = dispatch groups
    moe_stat_axes: tuple = ()           # mesh axes to psum router load stats
                                        # over (set inside shard_map bodies —
                                        # EP dispatch / pipeline stages — so
                                        # the aux loss sees global counts)
    remat_inner: bool = False           # additionally checkpoint each layer
                                        # inside a scanned block (hybrids)
    gather_params: Optional[Callable] = None
                                        # per-block-iteration FSDP de-gather
                                        # constraint (keeps the all-gather
                                        # inside the layer loop instead of
                                        # letting XLA hoist the whole stack)
    gather_prefetch: bool = False       # double-buffer the per-block gather:
                                        # issue layer l+1's gather at the
                                        # top of layer l's compute so it
                                        # overlaps ('ovl' strategy token)
    attn_impl: str = "jnp"              # 'jnp' | 'pallas' (TPU hot path)
    norm_impl: str = "jnp"              # 'jnp' | 'pallas' (fused rmsnorm VJP)
    constrain: Optional[Callable] = None  # (name, x) -> x sharding constraint
    # pipeline parallelism (schedule over a mesh axis, core/pipeline.py):
    # set by parallel.make_runtime when the plan has a 'pipe' axis
    pipeline_axis: str = ""             # mesh axis name ('' = no pipelining)
    pipeline_microbatches: int = 1      # M microbatches per (GA-)minibatch
    pipeline_mesh: Optional[object] = None   # Mesh the shard_map runs over
    pipeline_batch_axes: tuple = ()     # batch-dim mesh axes inside the pipe
    pipeline_schedule: str = "gpipe"    # 'gpipe' | '1f1b'
    pipeline_tp_axis: str = ""          # model axis to Megatron-compose
                                        # inside the stage (head_tp plans)
    pipeline_cp_axis: str = ""          # model axis to context-compose
                                        # inside the stage (context plans)
    pipeline_param_spec_fn: Optional[Callable] = None
                                        # (tree_path, ndim) -> PartitionSpec
                                        # for stage param leaves (stack dim
                                        # over 'pipe' + inner model/expert
                                        # sharding); None -> stack dim only
    # manual inner-mesh composition, active only inside a pipeline stage
    # body (set on the stage Runtime by transformer._pipeline_blocks):
    tp_reduce_axis: str = ""            # psum mixer/ffn outputs over this
                                        # axis (Megatron-TP inside shard_map)
    cp_axis: str = ""                   # attention gathers KV over this
                                        # axis (manual context parallelism)
    # expert parallelism (sharded all-to-all dispatch, core/expert.py):
    # set by parallel.make_runtime when the plan has an 'expert' axis
    expert_axis: str = ""               # mesh axis of the EP all-to-all
    expert_mesh: Optional[object] = None     # Mesh the EP shard_map runs over
    expert_token_axes: tuple = ()       # mesh axes sharding the token dim

    def c(self, name: str, x):
        """Apply a named sharding constraint if a parallel plan is active."""
        if self.constrain is None:
            return x
        return self.constrain(name, x)


DEFAULT_RUNTIME = Runtime()


# ---------------------------------------------------------------------------
# Megatron-TP reduction (manual shard_map composition)
# ---------------------------------------------------------------------------
# Inside a fully-manual shard_map (a pipeline stage) tensor parallelism
# reduces each sublayer's row-parallel partial output with a *raw*
# jax.lax.psum.  Raw — not a custom "logical" vjp — because jax's
# shard_map machinery differentiates the physical SPMD program: unmentioned
# output axes are implicitly pmean'd, unmentioned input cotangents are
# psummed, and psum transposes to psum, which together make the physical
# gradients equal the logical ones exactly (a hand-rolled identity-backward
# psum breaks that bookkeeping and mis-scales every gradient that crosses
# it).  The column-parallel input side needs no marker at all for the same
# reason.

def tp_reduce_out(x, rt: "Runtime"):
    """Sum a row-parallel sublayer's partial output over the model axis."""
    if not rt.tp_reduce_axis:
        return x
    return jax.lax.psum(x, rt.tp_reduce_axis)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg, key, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}
    return {"scale": jnp.ones((d,))}


def apply_norm(p, x, eps, rt: Optional["Runtime"] = None):
    if (rt is not None and rt.norm_impl == "pallas" and "bias" not in p
            and x.shape[-1] % 128 == 0):
        # fused Pallas rmsnorm (custom_vjp: backward is a kernel too);
        # layernorm and non-lane-aligned dims stay on the jnp path
        from repro.kernels import ops as kernel_ops
        return kernel_ops.rmsnorm(x, p["scale"], eps=eps)
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:            # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(scale, x, eps):
    """Per-head q/k RMSNorm (Qwen3). x: (..., head_dim)."""
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary / positional embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_angles(positions, head_dim, theta):
    """positions: (..., S) int -> angles (..., S, head_dim//2) fp32."""
    inv = rope_freqs(head_dim, theta)
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x, angles):
    """x: (B, S, H, D); angles: (B, S, D//2). Rotates pairs (x[2i], x[2i+1])
    laid out as two halves (llama convention)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    # angles: (B, S, d2) -> (B, S, 1, d2) to broadcast over heads
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def mrope_angles(position_ids, head_dim, theta, sections):
    """Qwen2-VL M-RoPE. position_ids: (3, B, S) for (t, h, w).

    Returns angles (B, S, head_dim//2) where frequency slots are split into
    three contiguous sections driven by the t/h/w position streams.
    """
    inv = rope_freqs(head_dim, theta)                      # (d2,)
    ang = position_ids.astype(jnp.float32)[..., None] * inv  # (3, B, S, d2)
    d2 = head_dim // 2
    assert sum(sections) == d2, (sections, d2)
    idx = np.zeros((d2,), dtype=np.int32)
    off = 0
    for s_i, sec in enumerate(sections):
        idx[off:off + sec] = s_i
        off += sec
    sel = jnp.asarray(idx)                                 # (d2,)
    # pick, per frequency slot, the angle stream named by `sel`
    return jnp.einsum("sbtd,ds->btd", ang, jax.nn.one_hot(sel, 3, axis=-1))


def sinusoidal_table(max_len: int, d_model: int) -> jnp.ndarray:
    pos = np.arange(max_len)[:, None]
    dim = np.arange(0, d_model, 2)[None, :]
    ang = pos / np.power(10000.0, dim / d_model)
    out = np.zeros((max_len, d_model), dtype=np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embed(cfg, key):
    k1, k2 = jax.random.split(key)
    p = {"tok": jax.random.normal(k1, (cfg.vocab_size, cfg.d_model)) * 0.02}
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(k2, (cfg.d_model, cfg.vocab_size)) \
            * (cfg.d_model ** -0.5)
    return p


def embed_tokens(p, tokens, rt: Runtime):
    w = p["tok"].astype(rt.compute_dtype)
    return rt.c("act_btd", jnp.take(w, tokens, axis=0))


def lm_logits(p, h, rt: Runtime):
    if "lm_head" in p:
        w = p["lm_head"].astype(rt.compute_dtype)
    else:
        w = p["tok"].astype(rt.compute_dtype).T
    return rt.c("logits", jnp.einsum("bsd,dv->bsv", h, w))


# ---------------------------------------------------------------------------
# dense FFN (SwiGLU / GELU / relu^2)
# ---------------------------------------------------------------------------

def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu2": lambda x: jnp.square(jax.nn.relu(x))}[name]


def init_mlp(cfg, key, d_ff=None):
    d, dff = cfg.d_model, d_ff or (cfg.dense_d_ff or cfg.d_ff)
    ks = jax.random.split(key, 3)
    scale_in, scale_out = d ** -0.5, dff ** -0.5
    p = {"w_up": jax.random.normal(ks[0], (d, dff)) * scale_in,
         "w_down": jax.random.normal(ks[1], (dff, d)) * scale_out}
    if cfg.glu:
        p["w_gate"] = jax.random.normal(ks[2], (d, dff)) * scale_in
    return p


def apply_mlp(cfg, p, x, rt: Runtime):
    act = _act(cfg.act)
    up = rt.c("act_btf", jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype)))
    if "w_gate" in p:
        gate = rt.c("act_btf", jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype)))
        h = act(gate) * up
    else:
        h = act(up)
    return rt.c("act_btd", jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype)))
