from repro.models.layers import Runtime, DEFAULT_RUNTIME
from repro.models.transformer import (
    init_params, init_cache, forward, loss_fn, prefill, decode_step,
    layer_plan, param_count_actual)

__all__ = [
    "Runtime", "DEFAULT_RUNTIME", "init_params", "init_cache", "forward",
    "loss_fn", "prefill", "decode_step", "layer_plan", "param_count_actual",
]
