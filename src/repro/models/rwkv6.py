"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free token mixing with
data-dependent decay.

Time-mix block:
  - ddlerp token shift: inputs for r/k/v/g/w are lerps between x_t and
    x_{t-1} with data-dependent (low-rank) mix coefficients;
  - per-channel decay w_t = exp(-exp(w0 + lora(x))), i.e. data-dependent;
  - WKV: per head (head_dim N) the state S in R^{N x N} evolves as
        S_t = diag(w_t) S_{t-1} + k_t^T v_t
        y_t = r_t (S_{t-1} + (u . k_t)^T v_t)
  - headwise groupnorm, silu(g) gate, output projection.

We provide a chunked parallel form (matmul-heavy, TPU friendly — the same
blocking the Pallas kernel in ``repro.kernels.rwkv6`` uses) and a one-step
recurrent form for decode; a pure sequential scan acts as the test oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Runtime

TM_RANK = 32   # low-rank dim of the token-shift ddlerp
TD_RANK = 64   # low-rank dim of the decay lora


def init_rwkv_time_mix(cfg, key):
    d = cfg.d_model
    H, N = cfg.rwkv_heads, cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    # decay bias init ~ -6..-5 => w ~ exp(-exp(-6)) ~ 0.9975 (stable chunks)
    w0 = -6.0 + 2.0 * jax.random.uniform(ks[0], (d,))
    return {
        "maa_x": jnp.zeros((d,)),
        "maa_rkvwg": jnp.zeros((5, d)),
        "tm_w1": jax.random.normal(ks[1], (d, 5 * TM_RANK)) * 1e-2,
        "tm_w2": jax.random.normal(ks[2], (5, TM_RANK, d)) * 1e-2,
        "w0": w0,
        "td_w1": jax.random.normal(ks[3], (d, TD_RANK)) * 1e-2,
        "td_w2": jax.random.normal(ks[4], (TD_RANK, d)) * 1e-2,
        "u": jax.random.normal(ks[5], (H, N)) * 1e-1,
        "wr": jax.random.normal(ks[6], (d, d)) * s,
        "wk": jax.random.normal(ks[7], (d, d)) * s,
        "wv": jax.random.normal(ks[8], (d, d)) * s,
        "wg": jax.random.normal(ks[9], (d, d)) * s,
        "wo": jax.random.normal(ks[10], (d, d)) * s,
        "ln_x": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
    }


def init_rwkv_channel_mix(cfg, key):
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "maa_k": jnp.zeros((d,)),
        "maa_r": jnp.zeros((d,)),
        "wk": jax.random.normal(ks[0], (d, dff)) * d ** -0.5,
        "wv": jax.random.normal(ks[1], (dff, d)) * dff ** -0.5,
        "wr": jax.random.normal(ks[2], (d, d)) * d ** -0.5,
    }


# ---------------------------------------------------------------------------
# WKV core
# ---------------------------------------------------------------------------

def wkv_recurrent(r, k, v, w, u, state):
    """Sequential oracle. r/k/v/w (B,T,H,N); u (H,N); state (B,H,N,N)."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                            # (B,H,N)
        kv = jnp.einsum("bhn,bhm->bhnm", k_t, v_t)
        y = jnp.einsum("bhn,bhnm->bhm", r_t, S) \
            + jnp.einsum("bhn,bhn,bhm->bhm", r_t, u[None] * k_t, v_t)
        S = w_t[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def wkv_chunked(r, k, v, w, u, state, chunk):
    """Chunked parallel WKV (fp32 internals).

    Derivation (per head, per key-channel n):
      cp_t  = prod_{l<=t} w_l  (within chunk; cp_0 = 1)
      y_t   = q'_t S_0 + sum_{j<t} ((q'_t . k'_j)) v_j + ((r_t u) . k_t) v_t
              with q'_t = r_t * cp_{t-1},  k'_j = k_j / cp_j
      S_C   = diag(cp_C) S_0 + sum_j (k_j * cp_C / cp_j)^T v_j
    """
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    Tp = -(-T // chunk) * chunk
    if Tp != T:
        # pad with identity steps: w=1 (no decay), k=0 (no contribution)
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        r, k, v = (jnp.pad(a, pad) for a in (r, k, v))
        w = jnp.pad(w, pad, constant_values=1.0)
    nc = Tp // chunk

    def to_chunks(x):
        return x.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)  # strict lower

    def chunk_step(S, inp):
        r_, k_, v_, w_ = (a.astype(jnp.float32) for a in inp)    # (B,C,H,N)
        lw = jnp.log(jnp.maximum(w_, 1e-12))
        lc = jnp.cumsum(lw, axis=1)                              # inclusive
        lc_prev = lc - lw                                        # exclusive
        qp = r_ * jnp.exp(lc_prev)
        kp = k_ * jnp.exp(-lc)
        A = jnp.einsum("bchn,bdhn->bhcd", qp, kp) * tri[None, None]
        diag = jnp.einsum("bchn,hn,bchn->bhc", r_, u.astype(jnp.float32), k_)
        y = (jnp.einsum("bhcd,bdhn->bchn", A, v_)
             + diag.transpose(0, 2, 1)[..., None] * v_
             + jnp.einsum("bchn,bhnm->bchm", qp, S))
        lc_tot = lc[:, -1]                                       # (B,H,N)
        k_tail = k_ * jnp.exp(lc_tot[:, None] - lc)
        S = jnp.exp(lc_tot)[..., None] * S \
            + jnp.einsum("bchn,bchm->bhnm", k_tail, v_)
        return S, y.astype(r.dtype)

    state, ys = jax.lax.scan(chunk_step, state.astype(jnp.float32), (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Tp, H, N)[:, :T]
    return y, state


def wkv_step(r, k, v, w, u, state):
    """One decode step. r/k/v/w (B,H,N); state (B,H,N,N)."""
    y = jnp.einsum("bhn,bhnm->bhm", r, state) \
        + jnp.einsum("bhn,bhn,bhm->bhm", r, u[None] * k, v)
    state = w[..., None] * state + jnp.einsum("bhn,bhm->bhnm", k, v)
    return y, state


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift lerp -> (xr, xk, xv, xw, xg), each (B,T,d)."""
    xx = x_prev - x
    xxx = x + xx * p["maa_x"]
    B, T, d = x.shape
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", xxx, p["tm_w1"].astype(x.dtype)))
    lora = lora.reshape(B, T, 5, TM_RANK)
    mix = jnp.einsum("btfr,frd->fbtd", lora, p["tm_w2"].astype(x.dtype))
    outs = []
    for i in range(5):
        outs.append(x + xx * (p["maa_rkvwg"][i].astype(x.dtype) + mix[i]))
    return outs


def _shift(x, last):
    """x_{t-1} stream: (B,T,d) shifted right, first slot = `last` (B,d)."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def rwkv_time_mix(cfg, p, x, rt: Runtime, state=None):
    """state: None (train: zeros, returns None) or dict with
    'x_prev' (B,d) and 'wkv' (B,H,N,N) for decode/prefill carry."""
    B, T, d = x.shape
    H, N = cfg.rwkv_heads, cfg.rwkv_head_dim
    last = state["x_prev"] if state is not None else jnp.zeros((B, d), x.dtype)
    S0 = (state["wkv"] if state is not None
          else jnp.zeros((B, H, N, N), jnp.float32))

    xr, xk, xv, xw, xg = _ddlerp(p, x, _shift(x, last))
    dt = x.dtype
    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(dt)).reshape(B, T, H, N)
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(dt)).reshape(B, T, H, N)
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(dt)).reshape(B, T, H, N)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"].astype(dt)))
    dlora = jnp.einsum("btr,rd->btd",
                       jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["td_w1"].astype(dt))),
                       p["td_w2"].astype(dt))
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) + dlora.astype(jnp.float32))
                         )).reshape(B, T, H, N)

    r, k, v = (rt.c("rwkv_heads", a) for a in (r, k, v))
    if T == 1 and state is not None:
        y, S = wkv_step(r[:, 0], k[:, 0], v[:, 0], w[:, 0].astype(jnp.float32),
                        p["u"].astype(jnp.float32), S0)
        y = y[:, None]
    elif (rt.attn_impl == "pallas" and state is None and T >= 64
          and N in (16, 32, 64, 128)):
        # TPU hot path: Pallas chunked WKV kernel (zero initial state)
        from repro.kernels import ops as kernel_ops
        y, S = kernel_ops.wkv6(r, k, v, w, p["u"], chunk=rt.rwkv_chunk)
    else:
        y, S = wkv_chunked(r, k, v, w, p["u"], S0, rt.rwkv_chunk)

    # headwise groupnorm
    yf = y.reshape(B, T, H, N).astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yf = yf.reshape(B, T, d) * p["ln_x"]["scale"] + p["ln_x"]["bias"]
    y = yf.astype(dt) * g
    out = jnp.einsum("btd,de->bte", y, p["wo"].astype(dt))

    new_state = None
    if state is not None:
        new_state = {"x_prev": x[:, -1], "wkv": S.astype(jnp.float32)}
    return rt.c("act_btd", out), new_state


def rwkv_channel_mix(cfg, p, x, rt: Runtime, state=None):
    B, T, d = x.shape
    last = state["x_prev"] if state is not None else jnp.zeros((B, d), x.dtype)
    xx = _shift(x, last) - x
    xk = x + xx * p["maa_k"].astype(x.dtype)
    xr = x + xx * p["maa_r"].astype(x.dtype)
    dt = x.dtype
    k = jnp.square(jax.nn.relu(
        rt.c("act_btf", jnp.einsum("btd,df->btf", xk, p["wk"].astype(dt)))))
    kv = jnp.einsum("btf,fd->btd", k, p["wv"].astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"].astype(dt)))
    new_state = {"x_prev": x[:, -1]} if state is not None else None
    return rt.c("act_btd", r * kv), new_state
