"""Mixture-of-Experts FFN: shared + routed top-k experts.

Two dispatch implementations:
  * ``dense``    — every expert computes every token, combined by router
                   weights.  Exact (no dropping); O(E/k) extra FLOPs.  Used
                   as the numerical oracle and for tiny smoke configs.
  * ``dropping`` — GShard-style fixed-capacity dispatch, but built with an
                   argsort over expert ids instead of a (T, E, C) one-hot
                   tensor, so memory is O(T·k·d + E·C·d).  This is the
                   production path: the (E, C, d) expert buffer shards as
                   (model=experts, data=capacity) and the scatter/gather
                   lowers to the all-to-all-like exchange the paper accounts
                   for in expert-parallel training.
"""
from __future__ import annotations

import math
import warnings

import jax
import jax.numpy as jnp

from repro.models.layers import Runtime, _act


def init_moe(cfg, key):
    m = cfg.moe
    d, f = cfg.d_model, m.expert_d_ff
    ks = jax.random.split(key, 5)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, m.n_experts)) * s_in,
        "w_up": jax.random.normal(ks[1], (m.n_experts, d, f)) * s_in,
        "w_down": jax.random.normal(ks[2], (m.n_experts, f, d)) * s_out,
    }
    if cfg.glu:
        p["w_gate"] = jax.random.normal(ks[3], (m.n_experts, d, f)) * s_in
    if m.n_shared_experts:
        fs = m.n_shared_experts * f
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {"w_up": jax.random.normal(kk[0], (d, fs)) * s_in,
                       "w_down": jax.random.normal(kk[1], (fs, d)) * (fs ** -0.5)}
        if cfg.glu:
            p["shared"]["w_gate"] = jax.random.normal(kk[2], (d, fs)) * s_in
    return p


def _router(cfg, p, xf, rt: Runtime = None):
    """xf (T, d) -> probs (T, E) fp32, weights/ids (T, k), aux loss.

    Inside a shard_map body (EP dispatch, pipeline stages) ``xf`` is the
    *local* token shard; ``rt.moe_stat_axes`` names the mesh axes to
    psum the load statistics over so the switch-style balance loss is
    computed from global counts — identical on every shard, and equal to
    what the single-device oracle computes on the full batch.
    """
    m = cfg.moe
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, m.top_k)             # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance loss.  Every shard holds the same local
    # token count, so the global fractions are the pmean of the local
    # ones — pmean keeps the divisor static (a traced token-count
    # denominator would become a scalar residual, which the shard_map
    # transpose cannot shard over the mesh axes)
    occupancy = jnp.zeros((m.n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac_tokens = occupancy / (xf.shape[0] * m.top_k)
    frac_probs = probs.mean(0)
    axes = tuple(rt.moe_stat_axes) if rt is not None else ()
    if axes:
        frac_tokens = jax.lax.pmean(frac_tokens, axes)
        frac_probs = jax.lax.pmean(frac_probs, axes)
    aux = m.n_experts * jnp.sum(frac_tokens * frac_probs) * m.aux_loss_coef
    return probs, weights, ids, aux


def _expert_ffn(cfg, p, buf, rt: Runtime):
    """buf (E, C, d) -> (E, C, d) through each expert's FFN.

    Under manual Megatron-TP (``rt.tp_reduce_axis`` inside a pipeline
    stage) the expert hidden dim is model-sharded and the partial w_down
    output is psummed by the caller's layer-level ``tp_reduce_out``."""
    act = _act(cfg.act)
    dt = buf.dtype
    up = rt.c("expert_hidden", jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt)))
    if "w_gate" in p:
        gate = rt.c("expert_hidden",
                    jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt)))
        h = act(gate) * up
    else:
        h = act(up)
    return rt.c("expert_buf", jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt)))


def _moe_dense(cfg, p, xf, rt: Runtime):
    """Oracle: all experts on all tokens."""
    m = cfg.moe
    probs, weights, ids, aux = _router(cfg, p, xf, rt)
    act = _act(cfg.act)
    dt = xf.dtype
    up = jnp.einsum("td,edf->etf", xf, p["w_up"].astype(dt))
    if "w_gate" in p:
        h = act(jnp.einsum("td,edf->etf", xf, p["w_gate"].astype(dt))) * up
    else:
        h = act(up)
    y_e = jnp.einsum("etf,efd->etd", h, p["w_down"].astype(dt))  # (E, T, d)
    w_full = jnp.zeros((xf.shape[0], m.n_experts), jnp.float32)
    w_full = w_full.at[jnp.arange(xf.shape[0])[:, None], ids].add(weights)
    y = jnp.einsum("etd,te->td", y_e, w_full.astype(dt))
    return y, aux


@jax.custom_vjp
def _routed_take(x, idx, inv_idx):
    """y[i] = x[idx[i]] (idx < 0 -> zero row).

    ``idx`` is an injective partial map and ``inv_idx`` its inverse, so the
    VJP is *also* a gather — no d-wide scatter ever reaches XLA (whose
    scatter lowering materializes huge u32 staging buffers, the dominant
    term in the baseline MoE memory profile; see EXPERIMENTS.md §Perf).
    """
    mask = (idx >= 0)[:, None].astype(x.dtype)
    return x[jnp.maximum(idx, 0)] * mask


def _routed_take_fwd(x, idx, inv_idx):
    return _routed_take(x, idx, inv_idx), (idx, inv_idx, x.shape[0])


def _routed_take_bwd(res, dy):
    idx, inv_idx, n = res
    mask = (inv_idx >= 0)[:, None].astype(dy.dtype)
    dx = dy[jnp.maximum(inv_idx, 0)] * mask
    return dx, None, None


_routed_take.defvjp(_routed_take_fwd, _routed_take_bwd)


def _route_capacity(fids, n_experts: int, capacity: int):
    """Index plumbing only (1-wide int ops): slot <-> item maps.

    fids (n_items,) int32 expert ids -> (dest (n_items,), inv (E*C,)):
    ``dest[i]`` is item i's slot in the (E, C) buffer (-1 = dropped),
    ``inv[s]`` the item occupying slot s (-1 = empty).  Shared by the
    grouped-dropping dispatch and the expert-parallel all-to-all path
    (core/expert.py), which routes into its *local* send buffer with the
    same maps.
    """
    n_items = fids.shape[0]
    E, C = n_experts, capacity
    order = jnp.argsort(fids, stable=True)
    sorted_ids = fids[order]
    counts = jnp.zeros((E,), jnp.int32).at[fids].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(n_items, dtype=jnp.int32) - starts[sorted_ids]
    keep_sorted = pos_sorted < C
    slot_sorted = sorted_ids * C + jnp.minimum(pos_sorted, C - 1)
    # item -> slot (dropped items -> -1)
    dest = jnp.full((n_items,), -1, jnp.int32).at[order].set(
        jnp.where(keep_sorted, slot_sorted, -1))
    # slot -> item (empty slots -> -1); dropped items scatter out of
    # bounds and are discarded by mode="drop"
    inv = jnp.full((E * C,), -1, jnp.int32).at[
        jnp.where(keep_sorted, slot_sorted, E * C)].set(
        order, mode="drop")
    return dest, inv


def _moe_dropping(cfg, p, xf, rt: Runtime):
    """Fixed-capacity dispatch with an explicit *group* dimension.

    Tokens are reshaped to (G, Tg, d) where G = number of data shards
    (``rt.moe_groups``); all routing index math (argsort, positions,
    capacity) is then purely per-group — GSPMD keeps it local to each data
    shard — and the only communication is the (E, G·Cg, d) expert-buffer
    reshard from group-sharded to expert-sharded layout: the expert-parallel
    all-to-all the paper's cost model accounts for.

    The d-wide data movement (items -> expert slots and back) is expressed
    with ``_routed_take``: gathers in both directions, scatter-free.
    """
    m = cfg.moe
    T, d = xf.shape
    k, E = m.top_k, m.n_experts
    probs, weights, ids, aux = _router(cfg, p, xf, rt)

    G = max(1, min(rt.moe_groups, T))
    while T % G:
        G //= 2
    Tg = T // G
    Cg = int(math.ceil(Tg * k * m.capacity_factor / E))
    Cg = max(8, -(-Cg // 8) * 8)                             # pad to 8

    xg = rt.c("moe_group_tokens", xf.reshape(G, Tg, d))
    idg = ids.reshape(G, Tg * k)                             # token-major
    wg = weights.reshape(G, Tg, k)

    dest_g, inv_g = jax.vmap(
        lambda fids: _route_capacity(fids, E, Cg))(idg)      # (G, Tg*k), (G, E*Cg)

    def dispatch_one(x_g, dest, inv):
        # token -> items without a gather (broadcast is scatter-free in bwd)
        x_items = jnp.broadcast_to(x_g[:, None], (Tg, k, d)).reshape(Tg * k, d)
        buf = _routed_take(x_items, inv, dest)               # (E*Cg, d)
        return buf.reshape(E, Cg, d)

    buf_g = jax.vmap(dispatch_one)(xg, dest_g, inv_g)        # (G, E, Cg, d)
    buf = buf_g.transpose(1, 0, 2, 3).reshape(E, G * Cg, d)
    buf = rt.c("expert_buf", buf)                            # all-to-all here

    out = _expert_ffn(cfg, p, buf, rt)                       # (E, G*Cg, d)
    out_g = rt.c("moe_group_buf",
                 out.reshape(E, G, Cg, d).transpose(1, 0, 2, 3))

    def combine_one(out_b, dest, inv, w_g):
        rows = _routed_take(out_b.reshape(E * Cg, d), dest, inv)  # (Tg*k, d)
        return (rows.reshape(Tg, k, d) * w_g[..., None].astype(rows.dtype)
                ).sum(axis=1)

    y = jax.vmap(combine_one)(out_g, dest_g, inv_g, wg)      # (G, Tg, d)
    return y.reshape(T, d), aux


def apply_moe(cfg, p, x, rt: Runtime):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    impl = rt.moe_impl
    if impl == "auto":
        impl = "dense" if B * S * cfg.moe.n_experts <= (1 << 22) else "dropping"
    if impl == "ep":
        # expert-parallel shard_map dispatch.  Token counts that cannot
        # tile every mesh axis (tiny decode batches) are zero-padded up to
        # the shard count and still run the real all-to-all — the plan the
        # planner priced.  Only a genuinely unshardable mesh (experts not
        # divisible over the axis) falls back to GSPMD dropping, loudly:
        # a silent fallback serves a different physical program than the
        # one the strategy ranking chose.
        from repro.core import expert as expert_lib
        if expert_lib.can_shard_tokens(cfg, rt, B * S):
            expert_lib.DISPATCH_STATS["ep_calls"] += 1
            y, aux = expert_lib.moe_expert_parallel(cfg, p, xf, rt)
        elif expert_lib.can_pad_tokens(cfg, rt):
            expert_lib.DISPATCH_STATS["ep_padded_calls"] += 1
            y, aux = expert_lib.moe_expert_parallel_padded(cfg, p, xf, rt)
        else:
            expert_lib.DISPATCH_STATS["ep_fallback_calls"] += 1
            warnings.warn(
                f"EP dispatch unavailable for {B * S} tokens on this mesh "
                f"(experts={cfg.moe.n_experts} do not shard over "
                f"{rt.expert_axis!r}); falling back to GSPMD dropping — "
                "this is a different physical program than the planned "
                "expert-parallel dispatch", stacklevel=2)
            impl = "dropping"
    if impl == "ep_manual":
        # already inside a manual shard_map (pipeline stage body): the
        # all-to-all runs on rt.expert_axis directly, no nested shard_map
        from repro.core import expert as expert_lib
        y, aux = expert_lib.moe_expert_parallel_manual(cfg, p, xf, rt)
    elif impl != "ep":
        y, aux = (_moe_dense if impl == "dense" else _moe_dropping)(cfg, p, xf, rt)
    y = y.reshape(B, S, d)
    if "shared" in p:
        sp = p["shared"]
        act = _act(cfg.act)
        dt = x.dtype
        up = jnp.einsum("bsd,df->bsf", x, sp["w_up"].astype(dt))
        if "w_gate" in sp:
            h = act(jnp.einsum("bsd,df->bsf", x, sp["w_gate"].astype(dt))) * up
        else:
            h = act(up)
        y = y + jnp.einsum("bsf,fd->bsd", h, sp["w_down"].astype(dt))
    return rt.c("act_btd", y), aux
