"""Mamba-1 selective SSM block (for Jamba, arXiv:2403.19887 style).

  x, z = in_proj(h)                        # (B,T,di) each, di = expand*d
  x    = silu(causal_conv1d(x))            # depthwise, width d_conv
  dt   = softplus(dt_proj(x_proj_dt(x)))   # (B,T,di)
  B_t, C_t = x_proj(x)                     # (B,T,ds) each
  h_t  = exp(dt_t * A) . h_{t-1} + (dt_t * x_t) outer B_t
  y_t  = C_t . h_t + D * x_t
  out  = out_proj(y * silu(z))

The scan runs chunked: an outer ``lax.scan`` over sequence chunks carries
the (B, di, ds) state; the inner per-chunk scan is wrapped in
``jax.checkpoint`` so the backward pass recomputes intra-chunk states
instead of storing (B, T, di, ds) activations (the standard Mamba-kernel
memory trade adapted to XLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Runtime


def _dt_rank(cfg):
    return cfg.mamba.dt_rank or -(-cfg.d_model // 16)


def init_mamba(cfg, key):
    d = cfg.d_model
    mc = cfg.mamba
    di = mc.expand * d
    dtr = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    A = jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32)[None], (di, 1))
    k_in1, k_in2 = jax.random.split(ks[0])
    return {
        # x/z projections kept separate so each shards cleanly on the
        # model axis (a fused (d, 2*di) matrix would straddle the split)
        "w_x_in": jax.random.normal(k_in1, (d, di)) * s,
        "w_z_in": jax.random.normal(k_in2, (d, di)) * s,
        "conv_w": jax.random.normal(ks[1], (mc.d_conv, di)) * (mc.d_conv ** -0.5),
        "conv_b": jnp.zeros((di,)),
        "w_x": jax.random.normal(ks[2], (di, dtr + 2 * mc.d_state)) * (di ** -0.5),
        "w_dt": jax.random.normal(ks[3], (dtr, di)) * (dtr ** -0.5),
        "b_dt": jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
            10 ** (jax.random.uniform(ks[4], (di,)) * 2.0 - 3.0))),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,)),
        "w_out": jax.random.normal(ks[5], (di, d)) * (di ** -0.5),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv. x (B,T,di), w (K,di). Returns (y, new_state).

    conv_state: (B, K-1, di) trailing inputs from the previous segment."""
    B, T, di = x.shape
    K = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, K - 1, di), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)            # (B, T+K-1, di)
    y = sum(xp[:, i:i + T] * w[i].astype(x.dtype) for i in range(K))
    new_state = xp[:, T:]                                    # last K-1 inputs
    return y + b.astype(x.dtype), new_state


def _selective_scan_chunk(dt, Bt, Ct, x, A, h0):
    """Sequential scan over one chunk. dt/x (B,C,di), Bt/Ct (B,C,ds),
    A (di,ds), h0 (B,di,ds) fp32. Returns (y (B,C,di), hC)."""
    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp                            # (B,di),(B,ds)...
        da = jnp.exp(dt_t[..., None] * A)                    # (B,di,ds)
        dbx = (dt_t * x_t)[..., None] * B_t[:, None, :]      # (B,di,ds)
        h = da * h + dbx
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (dt, Bt, Ct, x))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h


def selective_scan(dt, Bt, Ct, x, A, h0, chunk):
    """Chunked selective scan. Shapes as above with T = n_chunks * chunk."""
    B, T, di = x.shape
    ds = Bt.shape[-1]
    chunk = min(chunk, T)
    Tp = -(-T // chunk) * chunk
    if Tp != T:
        # pad with identity steps: dt=0 -> da=1, dbx=0 (state untouched)
        pad = [(0, 0), (0, Tp - T), (0, 0)]
        dt, Bt, Ct, x = (jnp.pad(a, pad) for a in (dt, Bt, Ct, x))
    nc = Tp // chunk

    def to_chunks(a):
        return a.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)

    inner = jax.checkpoint(lambda h, d_, b_, c_, x_:
                           _selective_scan_chunk(d_, b_, c_, x_, A, h))

    def outer(h, inp):
        d_, b_, c_, x_ = inp
        y, h = inner(h, d_, b_, c_, x_)
        return h, y

    h, ys = jax.lax.scan(outer, h0, tuple(map(to_chunks, (dt, Bt, Ct, x))))
    y = ys.transpose(1, 0, 2, 3).reshape(B, Tp, di)[:, :T]
    return y, h


def mamba_block(cfg, p, h, rt: Runtime, state=None):
    """state: None (train) or {'conv': (B,K-1,di), 'ssm': (B,di,ds)}."""
    B, T, d = h.shape
    mc = cfg.mamba
    di = mc.expand * d
    dtr = _dt_rank(cfg)
    dt_ = h.dtype

    x = rt.c("mamba_inner", jnp.einsum("btd,de->bte", h, p["w_x_in"].astype(dt_)))
    z = rt.c("mamba_inner", jnp.einsum("btd,de->bte", h, p["w_z_in"].astype(dt_)))
    conv_state = state["conv"] if state is not None else None
    x, new_conv = _causal_conv(x, p["conv_w"], p["conv_b"], conv_state)
    x = jax.nn.silu(x)
    x = rt.c("mamba_inner", x)

    proj = jnp.einsum("bte,ef->btf", x, p["w_x"].astype(dt_))
    dt_lr, B_t, C_t = jnp.split(proj, [dtr, dtr + mc.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,re->bte", dt_lr, p["w_dt"].astype(dt_))
        + p["b_dt"].astype(dt_))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (di, ds)

    h0 = (state["ssm"] if state is not None
          else jnp.zeros((B, di, mc.d_state), jnp.float32))
    if T == 1 and state is not None:
        y, hN = _selective_scan_chunk(dt, B_t, C_t, x, A, h0)
    else:
        y, hN = selective_scan(dt, B_t, C_t, x, A, h0, rt.mamba_chunk)
    y = y.astype(dt_) + p["D"].astype(dt_) * x
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"].astype(dt_))

    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "ssm": hN}
    return rt.c("act_btd", out), new_state
