"""Unified decoder-only model over all assigned architecture families.

A model is a stack of layers; each layer = (norm -> mixer -> residual,
norm -> ffn -> residual) where the mixer is attention / RWKV-6 / Mamba and
the ffn is dense MLP / MoE / RWKV channel-mix, both chosen per-layer by the
``ModelConfig`` (hybrids like Jamba interleave).

To keep compiled HLO small at 28-80 layers, layers are executed with
``lax.scan`` over *blocks*: ``layer_plan`` finds the shortest
(prefix, period) decomposition such that layers [start:] repeat a fixed
signature pattern of length ``period``; per-position parameters are stacked
over the ``n_blocks`` repeats and scanned (MaxText-style), with optional
remat per block.  KV/recurrent caches are stacked the same way and threaded
through the scan as xs/ys.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.layers import (Runtime, apply_norm, embed_tokens,
                                 init_embed, init_mlp, init_norm, apply_mlp,
                                 lm_logits, mrope_angles, rope_angles,
                                 tp_reduce_out)


# ---------------------------------------------------------------------------
# layer planning
# ---------------------------------------------------------------------------

def _sig(cfg: ModelConfig, i: int) -> Tuple[str, bool]:
    return (cfg.layer_kind(i), cfg.is_moe_layer(i))


def layer_plan(cfg: ModelConfig):
    """-> (prefix_layer_ids, start, period, n_blocks) minimizing unrolled size."""
    L = cfg.n_layers
    sigs = [_sig(cfg, i) for i in range(L)]
    for total in range(1, L + 1):
        for start in range(total):
            period = total - start
            if (L - start) % period:
                continue
            if all(sigs[start + j] == sigs[start + (j % period)]
                   for j in range(L - start)):
                return list(range(start)), start, period, (L - start) // period
    return list(range(L)), L, 1, 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, i: int, key) -> Dict[str, Any]:
    kind, is_moe = _sig(cfg, i)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg, k1), "norm2": init_norm(cfg, k2)}
    if kind == "attn":
        p["mixer"] = attn_lib.init_attention(cfg, k3)
    elif kind == "rwkv6":
        p["mixer"] = rwkv_lib.init_rwkv_time_mix(cfg, k3)
    elif kind == "mamba":
        p["mixer"] = mamba_lib.init_mamba(cfg, k3)
    else:
        raise ValueError(kind)
    if kind == "rwkv6":
        p["ffn"] = rwkv_lib.init_rwkv_channel_mix(cfg, k4)
    elif is_moe:
        p["ffn"] = moe_lib.init_moe(cfg, k4)
    else:
        p["ffn"] = init_mlp(cfg, k4)
    return p


def _tree_stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    prefix, start, period, n_blocks = layer_plan(cfg)
    keys = jax.random.split(key, cfg.n_layers + 2)
    params = {
        "embed": init_embed(cfg, keys[-1]),
        "final_norm": init_norm(cfg, keys[-2]),
        "prefix": [_init_layer(cfg, i, keys[i]) for i in prefix],
        "blocks": [
            _tree_stack([_init_layer(cfg, start + b * period + pos,
                                     keys[start + b * period + pos])
                         for b in range(n_blocks)])
            for pos in range(period)
        ] if n_blocks else [],
    }
    return params


def param_count_actual(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg, i, batch, max_len, dtype, rt: Runtime):
    kind, _ = _sig(cfg, i)
    d = cfg.d_model
    if kind == "attn":
        return {"kv": attn_lib.make_kv_cache(cfg, batch, max_len, dtype, rt)}
    if kind == "rwkv6":
        H, N = cfg.rwkv_heads, cfg.rwkv_head_dim
        return {
            "att": {"x_prev": jnp.zeros((batch, d), dtype),
                    "wkv": rt.c("rwkv_state",
                                jnp.zeros((batch, H, N, N), jnp.float32))},
            "ffn": {"x_prev": jnp.zeros((batch, d), dtype)},
        }
    if kind == "mamba":
        mc = cfg.mamba
        di = mc.expand * d
        return {"conv": jnp.zeros((batch, mc.d_conv - 1, di), dtype),
                "ssm": rt.c("mamba_state",
                            jnp.zeros((batch, di, mc.d_state), jnp.float32))}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype, rt: Runtime):
    prefix, start, period, n_blocks = layer_plan(cfg)
    return {
        "prefix": [_init_layer_cache(cfg, i, batch, max_len, dtype, rt)
                   for i in prefix],
        "blocks": [
            _tree_stack([_init_layer_cache(cfg, start + b * period + pos,
                                           batch, max_len, dtype, rt)
                         for b in range(n_blocks)])
            for pos in range(period)
        ] if n_blocks else [],
    }


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _apply_layer(cfg, sig, lp, h, rope_ang, rt: Runtime, cache=None,
                 paged=None):
    """-> (h, new_cache, aux_loss).

    With ``rt.tp_reduce_axis`` set (Megatron-TP inside a manual pipeline
    stage), the partial mixer/ffn outputs are psummed over the model axis
    — the classic two all-reduces per layer, placed exactly where the
    GSPMD lowering's sharding constraints would induce them.  (The
    column-parallel input side needs no marker: shard_map differentiates
    the physical program, so the psum's transpose and the spec-level
    psum/pmean bookkeeping produce exact gradients.)"""
    kind, is_moe = sig
    aux = jnp.zeros((), jnp.float32)

    x = apply_norm(lp["norm1"], h, cfg.norm_eps, rt)
    if kind == "attn":
        mix, new_mix_cache = attn_lib.attention_block(
            cfg, lp["mixer"], x, rope_ang, rt,
            cache=None if cache is None else cache["kv"], paged=paged)
        new_cache = None if cache is None else {"kv": new_mix_cache}
    elif kind == "rwkv6":
        mix, new_att = rwkv_lib.rwkv_time_mix(
            cfg, lp["mixer"], x, rt,
            state=None if cache is None else cache["att"])
        new_cache = None if cache is None else {"att": new_att}
    else:  # mamba
        mix, new_state = mamba_lib.mamba_block(
            cfg, lp["mixer"], x, rt,
            state=None if cache is None else cache)
        new_cache = new_state
    h = h + tp_reduce_out(mix, rt)

    x = apply_norm(lp["norm2"], h, cfg.norm_eps, rt)
    if kind == "rwkv6":
        ffn, new_ffn = rwkv_lib.rwkv_channel_mix(
            cfg, lp["ffn"], x, rt,
            state=None if cache is None else cache["ffn"])
        if new_cache is not None:
            new_cache["ffn"] = new_ffn
    elif is_moe:
        ffn, aux = moe_lib.apply_moe(cfg, lp["ffn"], x, rt)
    else:
        ffn = apply_mlp(cfg, lp["ffn"], x, rt)
    h = h + tp_reduce_out(ffn, rt)
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _sinusoidal_from_positions(positions, d_model, dtype):
    """positions (B,S) -> (B,S,d_model) classic sin/cos embedding."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return emb.astype(dtype)


def _embed_inputs(cfg, params, batch, rt: Runtime, positions):
    if "embeds" in batch:
        # audio-frontend stub: precomputed frame embeddings (train/prefill);
        # decode steps feed generated codec *tokens* through the embedding
        h = batch["embeds"].astype(rt.compute_dtype)
    else:
        h = embed_tokens(params["embed"], batch["tokens"], rt)
        if cfg.input_mode == "tokens+vision" and "vision_embeds" in batch:
            v = batch["vision_embeds"].astype(h.dtype)
            # fixed layout: the first V positions of the stream are patches
            v = v[:, :h.shape[1]]
            h = jnp.concatenate([v, h[:, v.shape[1]:]], axis=1)
    if cfg.pos_embed == "sinusoidal":
        h = h + _sinusoidal_from_positions(positions, cfg.d_model, h.dtype)
    return rt.c("act_btd", h)


def _rope_for(cfg, batch, positions):
    hd = cfg.head_dim_
    if cfg.rope == "none":
        return None
    if cfg.rope == "mrope":
        pos_ids = batch.get("position_ids")
        if pos_ids is None:                     # text-only fallback: t=h=w
            pos_ids = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return mrope_angles(pos_ids, hd, cfg.rope_theta, cfg.mrope_sections)
    return rope_angles(positions, hd, cfg.rope_theta)


def forward(cfg: ModelConfig, params, batch, rt: Runtime,
            cache=None) -> Tuple[jnp.ndarray, Optional[Any], jnp.ndarray]:
    """-> (logits, new_cache | None, aux_loss).

    batch: tokens (B,S) [or embeds (B,S,d)], optional position_ids (3,B,S),
    optional pos (scalar absolute offset, decode/continuation).
    """
    if "embeds" in batch:
        B, S = batch["embeds"].shape[:2]
    else:
        B, S = batch["tokens"].shape
    offset = batch.get("pos", jnp.zeros((), jnp.int32))
    positions = offset + jnp.arange(S, dtype=jnp.int32)[None]
    positions = jnp.broadcast_to(positions, (B, S))

    h = _embed_inputs(cfg, params, batch, rt, positions)
    rope_ang = _rope_for(cfg, batch, positions)

    prefix, start, period, n_blocks = layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    # paged serving state (block table + per-request context lengths) is
    # shared, read-only, across every layer: it rides next to the per-layer
    # pools in the cache dict and is closed over by the scan body rather
    # than threaded through it — the engine advances ctx between steps
    paged = cache.get("paged") if cache is not None else None

    if rt.pipeline_axis and cache is None:
        # GPipe path: the whole layer stack runs under core/pipeline.py's
        # shard_map schedule (embed / final norm / head stay on the plain
        # GSPMD path, replicated over the pipe axis).  Strategy.to_plan
        # only hands out pipeline runtimes for uniform stacks.
        if prefix or period != 1 or not n_blocks:
            raise ValueError(
                "pipeline runtime requires a uniform layer stack "
                "(no prefix, period 1); Strategy.to_plan validates this")
        h, aux_total = _pipeline_blocks(cfg, params, h, rope_ang, rt)
        h = apply_norm(params["final_norm"], h, cfg.norm_eps, rt)
        logits = lm_logits(params["embed"], h, rt)
        return logits, None, aux_total

    new_prefix_caches = []
    for j, i in enumerate(prefix):
        c = None if cache is None else cache["prefix"][j]
        h, nc, aux = _apply_layer(cfg, _sig(cfg, i), params["prefix"][j],
                                  h, rope_ang, rt, c, paged)
        aux_total += aux
        new_prefix_caches.append(nc)

    new_block_caches = None
    if n_blocks:
        sigs = [_sig(cfg, start + pos) for pos in range(period)]

        apply = _apply_layer
        if rt.remat_inner:
            # cfg, sig and rt are static (hashable frozen dataclasses)
            apply = jax.checkpoint(_apply_layer, static_argnums=(0, 1, 5))

        prefetch = rt.gather_prefetch and rt.gather_params is not None

        def block_fn(carry, xs):
            if prefetch:
                # double-buffered gather ('ovl'): the carry holds this
                # iteration's already-gathered slice; xs carries the
                # *next* iteration's shard, whose gather is issued here —
                # before this block's compute — so the collective runs
                # under it instead of serializing ahead of each block
                h_, aux_, lps = carry
                nxt = tuple(rt.gather_params(lp) for lp in xs[:period])
            else:
                h_, aux_ = carry
                lps = xs[:period]
            caches = xs[period:] if cache is not None else [None] * period
            new_caches = []
            for pos in range(period):
                lp = lps[pos]
                if not prefetch and rt.gather_params is not None:
                    # re-assert the de-gathered (replicated-over-fsdp) layout
                    # on the *per-iteration* slice: the all-gather is loop-
                    # variant and stays inside the scan (per-layer FSDP
                    # gather) instead of being hoisted over the whole stack.
                    lp = rt.gather_params(lp)
                h_, nc, a = apply(cfg, sigs[pos], lp, h_,
                                  rope_ang, rt, caches[pos], paged)
                aux_ += a
                new_caches.append(nc)
            ys = tuple(new_caches) if cache is not None else None
            new_carry = (h_, aux_, nxt) if prefetch else (h_, aux_)
            return new_carry, ys

        if rt.remat:
            block_fn = jax.checkpoint(block_fn)

        blocks = tuple(params["blocks"])
        if prefetch:
            # feed each iteration the next slice (rolled stack; the final
            # iteration's wrapped-around gather is dead and DCEs away) and
            # seed the buffer with slice 0's gather
            xs = tuple(jax.tree.map(lambda a: jnp.roll(a, -1, axis=0), b)
                       for b in blocks)
            g0 = tuple(rt.gather_params(jax.tree.map(lambda a: a[0], b))
                       for b in blocks)
            carry0 = (h, aux_total, g0)
        else:
            xs = blocks
            carry0 = (h, aux_total)
        if cache is not None:
            xs = xs + tuple(cache["blocks"])
        out_carry, ys = jax.lax.scan(block_fn, carry0, xs)
        h, aux_total = out_carry[0], out_carry[1]
        if cache is not None:
            new_block_caches = list(ys)

    h = apply_norm(params["final_norm"], h, cfg.norm_eps, rt)
    logits = lm_logits(params["embed"], h, rt)

    new_cache = None
    if cache is not None:
        new_cache = {"prefix": new_prefix_caches, "blocks": new_block_caches or []}
        if paged is not None:
            new_cache["paged"] = paged
    return logits, new_cache, aux_total


def pipeline_stage_runtime(rt: Runtime, rows: int) -> Runtime:
    """The stage-body Runtime for a pipeline microbatch of ``rows`` rows —
    the single recipe for every ``pipeline_apply`` caller (the forward
    path below AND ``perf/pipeline_probe.py``), so the two cannot drift.

    The stage body runs inside a fully-manual shard_map: named sharding
    constraints and per-block FSDP gathers are meaningless there; MoE
    router load stats psum over the token-sharding axes for a global aux.
    moe_groups=1: the stage already sees only its device-local token
    slice (the non-pp lowering's per-data-shard dispatch group) —
    keeping the global group count would subdivide it dp times further
    and shrink per-group expert capacity accordingly.  The manual
    tp/cp axes are activated, and EP plans switch to the in-stage
    ``ep_manual`` dispatch (which calls the expert all-to-all directly —
    no nested shard_map)."""
    from repro.core.pipeline import batch_axes_spec

    kept = batch_axes_spec(rt.pipeline_mesh, rt.pipeline_batch_axes, rows)
    tok_axes = kept + ((rt.pipeline_cp_axis,) if rt.pipeline_cp_axis else ())
    moe_impl = rt.moe_impl
    if rt.expert_axis and moe_impl == "ep":
        # the in-stage all-to-all needs the microbatch actually sharded
        # over the expert axis — with replicated tokens the duplicate
        # dispatch rows would overcount the expert grads
        if rt.expert_axis not in kept:
            raise ValueError(
                f"pipeline microbatch of {rows} rows does not shard "
                f"over the {rt.expert_axis!r} mesh axis "
                f"(size {rt.pipeline_mesh.shape[rt.expert_axis]}): the "
                "expert all-to-all inside a pipeline stage needs "
                "expert-sharded tokens — grow global_batch or lower "
                "grad_accum x microbatches")
        moe_impl = "ep_manual"
    return dataclasses.replace(rt, constrain=None, gather_params=None,
                               moe_stat_axes=tok_axes, moe_groups=1,
                               moe_impl=moe_impl,
                               tp_reduce_axis=rt.pipeline_tp_axis,
                               cp_axis=rt.pipeline_cp_axis)


def pipeline_stage_param_specs(rt: Runtime, stage_params):
    """PartitionSpecs for a stage-param pytree via the plan's
    ``pipeline_param_spec_fn`` (stack dim over 'pipe' + inner
    model/expert sharding); None when the runtime carries no spec fn.
    Shared by the forward path and the bubble probe so both lower the
    same physical program."""
    if rt.pipeline_param_spec_fn is None:
        return None
    return jax.tree_util.tree_map_with_path(
        lambda pth, leaf: rt.pipeline_param_spec_fn(pth, leaf.ndim),
        stage_params)


def _pipeline_blocks(cfg: ModelConfig, params, h, rope_ang, rt: Runtime):
    """Apply the full (uniform, stacked) layer stack under the plan's
    pipeline schedule (GPipe or 1F1B): split the batch into M
    microbatches, pipeline them over the mesh 'pipe' axis (stage p owns
    the contiguous layer slice the param sharding already placed there),
    and stitch the outputs back.

    The stage body computes over the *full inner mesh*: head_tp plans keep
    the stage params model-sharded (``rt.pipeline_param_spec_fn``) and run
    Megatron psums inside ``_apply_layer``; context plans shard the
    microbatch sequence over the model axis (attention gathers KV); expert
    plans dispatch MoE layers through ``core/expert.py``'s all-to-all on
    the expert axis.

    Returns (h, aux): the MoE load-balance loss is threaded through the
    schedule alongside each microbatch's activation and averaged over the
    M microbatches — the same per-microbatch averaging grad accumulation
    applies (each microbatch's balance stats are its own, psum-reduced
    across the token-sharding axes so every shard sees global counts)."""
    from repro.core.pipeline import make_pipelined_block_fn, pipeline_apply

    M = rt.pipeline_microbatches
    B = h.shape[0]
    if B % M:
        raise ValueError(
            f"batch {B} does not split into {M} pipeline microbatches "
            "(grad_accum x microbatches must divide the global batch)")
    rt_stage = pipeline_stage_runtime(rt, B // M)
    stage_fn = make_pipelined_block_fn(cfg, rt_stage)
    # training positions are identical across rows -> rope with batch dim 1
    # broadcasts over the (data-sharded) local microbatch inside the stage
    rope_mb = None if rope_ang is None else rope_ang[:1]
    x_mb = h.reshape((M, B // M) + h.shape[1:])
    stage_params = {"layers": params["blocks"][0]}
    pspecs = pipeline_stage_param_specs(rt, stage_params)
    out, aux = pipeline_apply(stage_fn, stage_params, x_mb,
                              rt.pipeline_mesh, rt.pipeline_axis,
                              extras=rope_mb,
                              batch_axes=rt.pipeline_batch_axes,
                              schedule=rt.pipeline_schedule,
                              param_specs=pspecs,
                              seq_axis=rt.pipeline_cp_axis,
                              tp_axis=rt.pipeline_tp_axis)
    return rt.c("act_btd", out.reshape((B,) + out.shape[2:])), aux / M


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params, batch, rt: Runtime):
    """Next-token cross entropy; labels < 0 are masked."""
    logits, _, aux = forward(cfg, params, batch, rt)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((lse - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll + aux, {"nll": nll, "aux": aux, "ntok": mask.sum()}


def prefill(cfg, params, batch, rt: Runtime, max_len: int):
    """Run the prompt through the model, building a decode cache."""
    if "tokens" in batch:
        B = batch["tokens"].shape[0]
    else:
        B = batch["embeds"].shape[0]
    cache = init_cache(cfg, B, max_len, rt.compute_dtype, rt)
    logits, cache, _ = forward(cfg, params, batch, rt, cache=cache)
    return logits, cache


def decode_step(cfg, params, cache, tokens, pos, rt: Runtime,
                extra: Optional[dict] = None):
    """tokens (B,1); pos scalar absolute position. -> (logits, cache)."""
    batch = {"tokens": tokens, "pos": pos}
    if extra:
        batch.update(extra)
    logits, cache, _ = forward(cfg, params, batch, rt, cache=cache)
    return logits, cache
