"""Attention: GQA with RoPE/M-RoPE, sliding windows, qk-norm, KV caches.

Two execution paths:
  * dense masked attention for short sequences / decode (1 query token);
  * a blocked online-softmax path (lax.scan over KV chunks inside a scan
    over Q chunks) so that S x S score matrices are never materialized --
    this is what makes 32k-prefill fit in ``memory_analysis`` and it is the
    pure-jnp oracle for the Pallas flash kernel in ``repro.kernels``.

All functions are batch-first: q (B, Sq, H, D), k/v (B, Skv, Kv, D).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (Runtime, apply_rope, rms_norm_headwise)

NEG_INF = -1e30


def init_attention(cfg, key):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd)) * s,
        "wk": jax.random.normal(ks[1], (d, kv * hd)) * s,
        "wv": jax.random.normal(ks[2], (d, kv * hd)) * s,
        "wo": jax.random.normal(ks[3], (h * hd, d)) * ((h * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,))
        p["bk"] = jnp.zeros((kv * hd,))
        p["bv"] = jnp.zeros((kv * hd,))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


# ---------------------------------------------------------------------------
# masking helpers
# ---------------------------------------------------------------------------

def _mask(q_pos, k_pos, window):
    """(..., Sq, Skv) boolean: causal (+ sliding window) visibility."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


# ---------------------------------------------------------------------------
# dense path
# ---------------------------------------------------------------------------

def _attend_dense(q, k, v, q_pos, k_pos, window, scale):
    """q (B,Sq,H,D), k/v (B,Skv,Kv,D); q_pos (Sq,), k_pos (Skv,)."""
    B, Sq, H, D = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    mask = _mask(q_pos, k_pos, window)                       # (Sq, Skv)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# blocked online-softmax path (flash-style, pure jnp)
# ---------------------------------------------------------------------------

def _attend_blocked(q, k, v, window, scale, q_chunk, kv_chunk):
    """Causal self-attention, q_pos == k_pos == arange(S).

    Scans KV chunks with running (max, denom, acc); scans Q chunks outside.
    Skips fully-masked KV chunks' contribution via masking (the scan itself
    still visits them; XLA removes the FLOPs only on TPU via the Pallas
    kernel -- here correctness + memory are what matter).

    Sequence lengths that are not a multiple of the chunk sizes are padded
    to the next common multiple; the causal mask excludes padded kv
    positions (k_pos > every real q_pos) and padded q rows are sliced off.
    """
    B, S, H, D = q.shape
    Kv = k.shape[2]
    G = H // Kv
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    mult = math.lcm(q_chunk, kv_chunk)
    Sp = -(-S // mult) * mult
    if Sp != S:
        pad = [(0, 0), (0, Sp - S), (0, 0), (0, 0)]
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    nq, nk = Sp // q_chunk, Sp // kv_chunk

    qg = q.reshape(B, nq, q_chunk, Kv, G, D).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, kv_chunk, Kv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, kv_chunk, Kv, D).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk                                   # qblk (B,qc,Kv,G,D)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj_kv):
            m_run, l_run, acc = carry
            kj, kblk, vblk = kj_kv
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk).astype(jnp.float32) * scale
            msk = _mask(q_pos, k_pos, window)                # (qc, kc)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Kv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Kv, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)                     # (B,Kv,G,qc,D)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    # outs: (nq, B, Kv, G, qc, D) -> (B, Sp, H, D) -> drop padded rows
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp, H, D)
    return out[:, :S]


def sdpa_causal(q, k, v, window=0, rt: Optional[Runtime] = None):
    """Self-attention where q/k/v cover the same positions 0..S-1."""
    rt = rt or Runtime()
    S = q.shape[1]
    scale = q.shape[-1] ** -0.5
    if rt.attn_impl == "pallas" and S >= 128 and q.shape[-1] % 64 == 0:
        # TPU hot path: Pallas flash kernel (interpret-mode on CPU)
        from repro.kernels import ops as kernel_ops
        return kernel_ops.attention(q, k, v, window=window)
    if S <= rt.attn_min_chunked_len:
        pos = jnp.arange(S)
        return _attend_dense(q, k, v, pos, pos, window, scale)
    return _attend_blocked(q, k, v, window, scale, rt.attn_q_chunk, rt.attn_kv_chunk)


def sdpa_decode(q, k_cache, v_cache, k_pos, cur_pos, window=0):
    """One-token decode: q (B,1,H,D) against cache (B,Sc,Kv,D).

    k_pos: (Sc,) absolute position held in each cache slot (-1 = empty);
    cur_pos: scalar position of the query token.
    """
    scale = q.shape[-1] ** -0.5
    B, Sq, H, D = q.shape
    Kv = k_cache.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32) * scale
    valid = (k_pos >= 0) & (k_pos <= cur_pos)
    if window:
        valid &= k_pos > (cur_pos - window)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v_cache)
    return out.reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# paged KV cache path (serving: shared block pools + per-request tables)
# ---------------------------------------------------------------------------

def _paged_write(pool, vals, tbl, pos):
    """Scatter vals (B, S, Kv, D) into pool (P, bs, Kv, D) at absolute
    positions pos (B, S) via the block table tbl (B, max_blocks).

    Position p of request b lands at (tbl[b, p // bs], p % bs).  Writes
    to unallocated blocks (tbl -1) or past the table are *dropped* — this
    is what makes inactive slots in a fixed-shape decode batch harmless:
    their sentinel positions fall outside any allocated block.
    """
    P, bs = pool.shape[0], pool.shape[1]
    nb = tbl.shape[1]
    blk_log = pos // bs
    blk = jnp.take_along_axis(tbl, jnp.clip(blk_log, 0, nb - 1), axis=1)
    blk = jnp.where((blk < 0) | (blk_log >= nb), P, blk)   # P = out of bounds
    off = pos % bs
    B, S = pos.shape
    return pool.at[blk.reshape(-1), off.reshape(-1)].set(
        vals.reshape((B * S,) + vals.shape[2:]).astype(pool.dtype),
        mode="drop")


def _paged_attend(q, k_pool, v_pool, tbl, q_pos, n_valid, window=0):
    """Attention over pool-gathered KV with per-request positions (jnp
    reference path; the Pallas flash-decode kernel replaces it on TPU).

    q (B, Sq, H, D) at absolute positions q_pos (B, Sq); n_valid (B,)
    counts KV entries present per request (the just-written chunk
    included), so both chunked prefill (Sq > 1) and decode (Sq == 1) are
    the same computation.
    """
    P, bs, Kv, D = k_pool.shape
    B, Sq = q_pos.shape
    nb = tbl.shape[1]
    safe = jnp.clip(tbl, 0, P - 1)
    k = k_pool[safe].reshape(B, nb * bs, Kv, D)
    v = v_pool[safe].reshape(B, nb * bs, Kv, D)
    k_pos = jnp.broadcast_to(jnp.arange(nb * bs)[None], (B, nb * bs))
    valid = (k_pos < n_valid[:, None]) & (tbl >= 0).repeat(bs, axis=1)
    mask = valid[:, None, :] & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    G = q.shape[2] // Kv
    qg = q.reshape(B, Sq, Kv, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * \
        (D ** -0.5)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, Kv * G, D)


def _paged_attention_block(cfg, q, k, v, cache, paged, rt: Runtime):
    """Write the new chunk into the layer's pools and attend against the
    request's full paged context.  cache: {'k_pool', 'v_pool'}; paged:
    {'tbl' (B, max_blocks), 'ctx' (B,)} shared across layers (the engine
    advances ctx between steps — layers only read it)."""
    B, S = q.shape[0], q.shape[1]
    tbl, ctx = paged["tbl"], paged["ctx"]
    pos = ctx[:, None] + jnp.arange(S, dtype=jnp.int32)[None]   # (B, S)
    k_pool = _paged_write(cache["k_pool"], k, tbl, pos)
    v_pool = _paged_write(cache["v_pool"], v, tbl, pos)
    n_valid = ctx + S
    if (S == 1 and rt.attn_impl == "pallas" and not cfg.sliding_window
            and cfg.head_dim_ % 8 == 0):
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.paged_decode_attention(q, k_pool, v_pool, tbl,
                                                n_valid)
    else:
        out = _paged_attend(q, k_pool, v_pool, tbl, pos, n_valid,
                            cfg.sliding_window)
    return out, {"k_pool": k_pool, "v_pool": v_pool}


# ---------------------------------------------------------------------------
# full attention block (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, x, rt: Runtime):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim_
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rms_norm_headwise(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm_headwise(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _cp_attend(q, k, v, window, scale, axis):
    """Manual context parallelism inside a shard_map stage: q/k/v hold this
    rank's contiguous sequence shard; K/V are all-gathered over ``axis``
    (gathered-KV exact attention) and the causal mask is offset by the
    rank's global position."""
    S_loc = q.shape[1]
    k_full = jax.lax.all_gather(k, axis, axis=1, tiled=True)
    v_full = jax.lax.all_gather(v, axis, axis=1, tiled=True)
    idx = jax.lax.axis_index(axis)
    q_pos = idx * S_loc + jnp.arange(S_loc)
    k_pos = jnp.arange(k_full.shape[1])
    return _attend_dense(q, k_full, v_full, q_pos, k_pos, window, scale)


def attention_block(cfg, p, x, rope_ang, rt: Runtime, cache=None,
                    want_cache: bool = False, paged=None):
    """Full attention sublayer.

    Train/prefill: x (B,S,d), cache None -> (out, new_cache | None).
    Decode:        x (B,1,d), cache dict  -> (out, updated cache).
    Paged serving: cache {'k_pool','v_pool'} + paged {'tbl','ctx'} —
                   chunked prefill (S>1) and decode (S==1) both append at
                   the request's ctx and attend over its block chain.
    """
    B, S, _ = x.shape
    if rt.cp_axis and rope_ang is not None:
        # manual CP: x carries only this rank's sequence shard — slice the
        # (full-length, batch-dim-1) rope angles down to its positions
        idx = jax.lax.axis_index(rt.cp_axis)
        rope_ang = jax.lax.dynamic_slice_in_dim(rope_ang, idx * S, S, axis=1)
    q, k, v = _project_qkv(cfg, p, x, rt)
    if rope_ang is not None:
        q = apply_rope(q, rope_ang)
        k = apply_rope(k, rope_ang)
    q = rt.c("heads_q", q)
    k = rt.c("heads_kv", k)
    v = rt.c("heads_kv", v)

    if paged is not None:
        out, new_cache = _paged_attention_block(cfg, q, k, v, cache, paged, rt)
    elif cache is None:
        if rt.cp_axis:
            out = _cp_attend(q, k, v, cfg.sliding_window,
                             q.shape[-1] ** -0.5, rt.cp_axis)
        else:
            out = sdpa_causal(q, k, v, cfg.sliding_window, rt)
        new_cache = None
        if want_cache:
            new_cache = make_kv_cache(cfg, B, S, k.dtype, rt)
            new_cache = prefill_kv_cache(new_cache, k, v, rt)
    elif S > 1:
        # prefill into a pre-allocated decode cache
        out = sdpa_causal(q, k, v, cfg.sliding_window, rt)
        new_cache = prefill_kv_cache(cache, k, v, rt)
    else:
        idx = cache["idx"]                                   # scalar int32
        Sc = cache["k"].shape[1]
        # ring arithmetic: position p lives at slot p % Sc.  For full-attn
        # caches Sc == max seq so this is the identity.
        slot = idx % Sc
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        k_pos = jax.lax.dynamic_update_slice(
            cache["kpos"], idx[None].astype(cache["kpos"].dtype), (slot,))
        k_cache = rt.c("kv_cache", k_cache)
        v_cache = rt.c("kv_cache", v_cache)
        out = sdpa_decode(q, k_cache, v_cache, k_pos, idx, cfg.sliding_window)
        new_cache = {"k": k_cache, "v": v_cache, "kpos": k_pos, "idx": idx + 1}

    out = out.reshape(B, S, -1)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(out.dtype))
    return rt.c("act_btd", out), new_cache


def make_kv_cache(cfg, batch, seq_len, dtype, rt: Runtime):
    """Empty cache. SWA archs keep a window-sized ring buffer."""
    size = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    kv, hd = cfg.kv_heads, cfg.head_dim_
    return {
        "k": rt.c("kv_cache", jnp.zeros((batch, size, kv, hd), dtype)),
        "v": rt.c("kv_cache", jnp.zeros((batch, size, kv, hd), dtype)),
        "kpos": jnp.full((size,), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }


def prefill_kv_cache(cache, k, v, rt: Runtime):
    """Write a full prefix of k/v (B,S,Kv,D) into a fresh cache."""
    S = k.shape[1]
    Sc = cache["k"].shape[1]
    if S >= Sc:          # SWA: keep last Sc positions, ring-consistent layout
        shift = (S - Sc) % Sc
        ks = jnp.roll(k[:, S - Sc:], shift, axis=1)
        vs = jnp.roll(v[:, S - Sc:], shift, axis=1)
        kpos = jnp.roll(jnp.arange(S - Sc, S, dtype=jnp.int32), shift)
        kc = rt.c("kv_cache", ks.astype(cache["k"].dtype))
        vc = rt.c("kv_cache", vs.astype(cache["v"].dtype))
    else:
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        kpos = jnp.where(jnp.arange(Sc) < S, jnp.arange(Sc), -1).astype(jnp.int32)
        kc, vc = rt.c("kv_cache", kc), rt.c("kv_cache", vc)
    return {"k": kc, "v": vc, "kpos": kpos,
            "idx": jnp.asarray(S, jnp.int32)}
