"""Training loop: jitted sharded train step, gradient accumulation,
metrics, checkpoint hooks.

``make_train_step`` is also what the multi-pod dry-run lowers: it closes
over (cfg, plan, runtime) and maps (params, opt_state, batch) ->
(params, opt_state, metrics) with every input/output sharded per the plan.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import parallel as par
from repro.models import transformer as tfm
from repro.models.layers import Runtime
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.optim.schedule import linear_warmup_cosine
from repro import telemetry as tel


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    warmup: int = 10
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_dir: str = ""
    grad_accum: int = 1
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    # resilience: async checkpointing + kill/resume (resilience subsystem)
    ckpt_async: bool = False        # snapshot on-thread, write in background
    ckpt_max_in_flight: int = 2     # bounded queued background writes
    ckpt_keep: int = 0              # gc all but the newest N (0 = keep all)
    resume: bool = False            # restore latest *valid* ckpt_dir state


def make_train_step(cfg: ModelConfig, rt: Runtime, tc: TrainConfig,
                    total_steps: Optional[int] = None):
    """Pure (params, opt_state, batch) -> (params, opt_state, metrics)."""
    total = total_steps or tc.steps

    def train_step(params, opt_state, batch):
        B = batch["labels"].shape[0]
        if B % max(tc.grad_accum, 1):
            raise ValueError(
                f"batch {B} does not split into grad_accum={tc.grad_accum}")
        if rt.pipeline_microbatches > 1 and \
                (B // max(tc.grad_accum, 1)) % rt.pipeline_microbatches:
            # GA slices the batch first; each GA microbatch is then split
            # into M pipeline microbatches — both must compose exactly
            raise ValueError(
                f"batch {B} / grad_accum {tc.grad_accum} does not split "
                f"into {rt.pipeline_microbatches} pipeline microbatches")

        def loss(p):
            return tfm.loss_fn(cfg, p, batch, rt)

        if tc.grad_accum > 1:
            # split the local batch into microbatches along dim 0
            def slice_mb(i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // tc.grad_accum),
                        x.shape[0] // tc.grad_accum, 0)
                    if getattr(x, "ndim", 0) > 0 else x, batch)

            def value_grad(mb):
                return jax.value_and_grad(
                    lambda p: tfm.loss_fn(cfg, p, mb, rt),
                    has_aux=True)(params)

            def micro(i, acc):
                g_acc, l_acc, m_acc = acc
                (l, m), g = value_grad(slice_mb(i))
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, l_acc + l, m_acc)

            # microbatch 0 runs unrolled: its aux dict gives the fori_loop
            # carry its structure, so the GA path returns the same metrics
            # keys the GA=1 path does instead of discarding them
            (l0, m0), g0 = value_grad(slice_mb(0))
            g0 = jax.tree.map(lambda g: g.astype(rt.grad_dtype), g0)
            grads, lsum, msum = jax.lax.fori_loop(
                1, tc.grad_accum, micro, (g0, l0, m0))
            grads = jax.tree.map(lambda g: g / tc.grad_accum, grads)
            loss_val = lsum / tc.grad_accum
            # token counts add across microbatches; everything else is a
            # per-microbatch mean
            metrics: Dict[str, Any] = {
                k: v if k == "ntok" else v / tc.grad_accum
                for k, v in msum.items()}
        else:
            (loss_val, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params)

        lr_scale = linear_warmup_cosine(opt_state["step"], tc.warmup, total)
        params, opt_state, opt_metrics = adamw_update(
            tc.opt, params, grads, opt_state, lr_scale)
        out = {"loss": loss_val, **metrics, **opt_metrics}
        return params, opt_state, out

    return train_step


def place_train_state(cfg: ModelConfig, plan: par.ParallelPlan, params,
                      opt_state, batch):
    """device_put existing (params, opt_state, batch) into the plan's
    shardings -> (params, opt_state, batch, pshard, oshard).

    The equivalence tests and benchmarks all need this exact layout (m/v
    shard like params, scalar step replicated, batch per batch_specs);
    one helper keeps the convention from drifting between call sites.
    Call under ``par.use_mesh(plan.mesh)``.
    """
    pshard = par.param_shardings(cfg, plan, jax.eval_shape(lambda: params))
    oshard = {"m": pshard, "v": pshard,
              "step": par.fitted(plan, par.P(), ())}
    return (jax.device_put(params, pshard),
            jax.device_put(opt_state, oshard),
            jax.device_put(batch, par.batch_specs(cfg, plan, batch)),
            pshard, oshard)


def shard_train_state(cfg: ModelConfig, plan: par.ParallelPlan, key,
                      rt: Runtime):
    """Initialize params + opt state directly into their shardings."""
    def init(k):
        p = tfm.init_params(cfg, k)
        if rt.param_dtype != jnp.float32:
            # storage-dtype policies (e.g. a pure-bf16 Runtime); the bf16
            # mixed-precision policy keeps f32 master params so this is
            # a no-op there
            p = jax.tree.map(
                lambda x: x.astype(rt.param_dtype)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, p)
        return p

    pshapes = jax.eval_shape(init, key)
    pshard = par.param_shardings(cfg, plan, pshapes)

    params = jax.jit(init, out_shardings=pshard)(key)
    oshapes = jax.eval_shape(init_opt_state, pshapes)
    oshard = {"m": pshard, "v": pshard,
              "step": par.fitted(plan, par.P(), ())}
    opt_state = jax.jit(init_opt_state, out_shardings=oshard)(params)
    return params, opt_state, pshard, oshard


def _restore_state(tc: TrainConfig, params, opt_state, pshard, oshard):
    """Resume support: restore (params, opt_state, meta) from the newest
    checkpoint in ``tc.ckpt_dir`` that passes CRC validation, or return
    the freshly initialized state when none exists."""
    from repro import checkpointing as ckpt_lib

    step = ckpt_lib.latest_valid_step(tc.ckpt_dir, verify=True)
    if step is None:
        return params, opt_state, 0, {}
    tree = ckpt_lib.restore_checkpoint(
        tc.ckpt_dir, step, {"params": params, "opt": opt_state},
        shardings={"params": pshard, "opt": oshard})
    meta = ckpt_lib.load_meta(tc.ckpt_dir, step)
    start = int(meta.get("step", step))
    print(f"[resume] restored step {start} from {tc.ckpt_dir}", flush=True)
    return tree["params"], tree["opt"], start, meta


def train_loop(cfg: ModelConfig, plan: par.ParallelPlan, rt: Runtime,
               tc: TrainConfig, batches, key=None,
               hooks: Optional[Callable] = None, fault_plan=None,
               telemetry: tel.Recorder = tel.NULL,
               drift: Optional[tel.DriftMonitor] = None):
    """Full driver: init, jit with shardings, iterate, log, checkpoint.

    ``tc.resume`` restores params/opt_state/PRNG/data position from the
    newest *valid* checkpoint in ``tc.ckpt_dir`` (CRC-verified; corrupt
    or partial saves are skipped), and the resumed run consumes the data
    stream from the restored position — a killed-and-resumed run is
    bit-identical to an uninterrupted one.  ``fault_plan``
    (:class:`repro.resilience.FaultPlan`) injects crashes (raised as
    ``SimulatedFailure`` before the scheduled step runs), straggler
    sleeps, and transient checkpoint-I/O errors (retried once).

    ``telemetry`` records per-step ``train/step`` spans (with
    ``train/dispatch``/``train/data``/``train/ckpt``/``train/wait``
    children) and window gauges (wps, steps/s, goodput fraction,
    measured MFU when ``drift`` carries the flops budget);
    ``drift`` (a :class:`repro.telemetry.DriftMonitor` built from the
    resolved strategy's ``StepReport.decomposition()``) gets one
    measured window per logging window.
    """
    from repro import checkpointing as ckpt_lib

    key = key if key is not None else jax.random.PRNGKey(0)
    with par.use_mesh(plan.mesh):
        params, opt_state, pshard, oshard = shard_train_state(cfg, plan, key, rt)
        start_step = 0
        if tc.resume and tc.ckpt_dir:
            params, opt_state, start_step, meta = _restore_state(
                tc, params, opt_state, pshard, oshard)
            if meta.get("prng") is not None:
                # save() wrote the raw key data; rebuild the key with the
                # same impl so a resumed run draws the bits an
                # uninterrupted one would (previously this was silently
                # dropped and resume re-used the caller's key object)
                kd = jnp.asarray(np.asarray(meta["prng"], dtype=np.uint32))
                if jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key):
                    key = jax.random.wrap_key_data(
                        kd, impl=jax.random.key_impl(key))
                else:
                    key = kd
        step_fn = make_train_step(cfg, rt, tc)

        checkpointer = None
        if tc.ckpt_every and tc.ckpt_async:
            checkpointer = ckpt_lib.AsyncCheckpointer(
                tc.ckpt_dir, max_in_flight=tc.ckpt_max_in_flight,
                keep=tc.ckpt_keep)

        def save(step, params, opt_state):
            kd = (jax.random.key_data(key)
                  if jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key)
                  else key)
            meta = {"step": step, "batches_consumed": step,
                    "prng": np.asarray(kd).tolist()}
            tree = {"params": params, "opt": opt_state}
            # one retry: the injected checkpoint-I/O faults are transient
            for attempt in range(2):
                try:
                    if fault_plan is not None:
                        fault_plan.ckpt_io_check(step)
                    if checkpointer is not None:
                        checkpointer.save(step, tree, meta=meta)
                    else:
                        ckpt_lib.save_checkpoint(tc.ckpt_dir, step, tree,
                                                 meta=meta)
                        if tc.ckpt_keep:
                            ckpt_lib.gc_checkpoints(tc.ckpt_dir,
                                                    keep=tc.ckpt_keep)
                    return
                except ckpt_lib.CheckpointIOError as e:
                    if attempt:
                        raise
                    print(f"[ckpt] transient I/O error at step {step}, "
                          f"retrying: {e}", flush=True)

        # data-pipeline position: a resumed run must see exactly the
        # batches an uninterrupted run would have seen from this step
        if start_step and hasattr(batches, "at"):
            it = iter(batches.at(start_step))
        else:
            it = iter(batches)
            for _ in range(start_step):
                next(it)
        first = next(it)
        bshard = par.batch_specs(cfg, plan, first)
        jstep = jax.jit(step_fn,
                        in_shardings=(pshard, oshard, bshard),
                        out_shardings=(pshard, oshard, None),
                        donate_argnums=(0, 1))

        history = []
        t0 = time.time()
        t_step_ema = 0.0
        batch = first
        tokens_per_step = int(np.asarray(first["labels"]).size)
        # Straggler injection scales a *measured* step time, so only a
        # fault plan that actually schedules stragglers justifies the
        # every-step host sync; crash/ckpt-io-only plans (and plain
        # runs) sync just on logging windows and keep dispatch async.
        sync_every_step = fault_plan is not None and any(
            e.kind == "straggler" for e in fault_plan.events)
        win_t0 = time.time()
        win_start = start_step
        win_ckpt = win_dispatch = win_wait = win_data = 0.0
        try:
            for step in range(start_step, tc.steps):
              with telemetry.span("train/step", step_num=step):
                if fault_plan is not None:
                    fault_plan.check_crash(step)
                    mult = fault_plan.delay_multiplier(step)
                    if mult > 1.0 and t_step_ema > 0.0:
                        time.sleep((mult - 1.0) * t_step_ema)
                t1 = time.time()
                with telemetry.span("train/dispatch"):
                    params, opt_state, metrics = jstep(params, opt_state,
                                                       batch)
                t2 = time.time()
                win_dispatch += t2 - t1
                if step + 1 < tc.steps:
                    with telemetry.span("train/data"):
                        batch = next(it)
                win_data += time.time() - t2
                if tc.ckpt_every and (step + 1) % tc.ckpt_every == 0:
                    t3 = time.time()
                    with telemetry.span("train/ckpt", step=step + 1):
                        save(step + 1, params, opt_state)
                    win_ckpt += time.time() - t3
                log_now = (step + 1) % tc.log_every == 0 or \
                    step == start_step
                if sync_every_step or log_now:
                    t4 = time.time()
                    with telemetry.span("train/wait"):
                        jax.block_until_ready(metrics["loss"])
                    win_wait += time.time() - t4
                    dt_step = time.time() - t1
                    t_step_ema = dt_step if t_step_ema == 0.0 else \
                        0.7 * t_step_ema + 0.3 * dt_step
                if log_now:
                    m = {k: float(v) for k, v in metrics.items()
                         if getattr(v, "ndim", 0) == 0}
                    dt = time.time() - t0
                    m["steps_per_s"] = (step + 1 - start_step) / dt
                    history.append({"step": step + 1, **m})
                    print(f"step {step+1:5d}  loss {m.get('loss', float('nan')):.4f}"
                          f"  gnorm {m.get('grad_norm', float('nan')):.3f}"
                          f"  {m['steps_per_s']:.2f} it/s", flush=True)
                    n_win = step + 1 - win_start
                    dt_win = time.time() - win_t0
                    if n_win > 0 and dt_win > 0:
                        telemetry.gauge("train/wps",
                                        tokens_per_step * n_win / dt_win)
                        telemetry.gauge("train/steps_per_s",
                                        n_win / dt_win)
                        telemetry.gauge("train/goodput_frac",
                                        max(0.0, 1.0 - win_ckpt / dt_win))
                        if drift is not None:
                            fl = drift.meta.get("model_flops_per_step")
                            peak = drift.meta.get("cluster_peak_flops")
                            if fl and peak:
                                telemetry.gauge(
                                    "train/mfu",
                                    fl / (dt_win / n_win) / peak)
                            drift.observe(
                                {"step": dt_win / n_win,
                                 "dispatch": win_dispatch / n_win,
                                 "wait": win_wait / n_win,
                                 "data": win_data / n_win},
                                n_steps=n_win)
                    win_t0 = time.time()
                    win_start = step + 1
                    win_ckpt = win_dispatch = win_wait = win_data = 0.0
                    if hooks:
                        hooks(step + 1, params, m)
        finally:
            if checkpointer is not None:
                checkpointer.close()
        return params, opt_state, history
