from repro.train.trainer import TrainConfig, make_train_step, train_loop, shard_train_state

__all__ = ["TrainConfig", "make_train_step", "train_loop", "shard_train_state"]
