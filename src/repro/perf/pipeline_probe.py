"""Execute the GPipe schedule and *measure* its bubble fraction.

The cost model charges pipeline parallelism a bubble of (P-1)/(M+P-1)
(``costmodel.step_time`` / ``pipeline.bubble_fraction``).  This probe
validates that analytic term against execution: it runs the exact
``pipeline_apply`` lowering a ``Strategy(pp>1)`` trains with (fwd + bwd,
real stage params) at fixed microbatch *size* for M and 2M microbatches,
fits t(M) = t_tick * (M + P - 1) + overhead, and reports

    bubble_measured = (P - 1) * t_tick / t(M)

Used by ``launch/dryrun.py --measure_bubble`` (written into the dryrun
artifact next to the prediction) and ``benchmarks/run.py --pp-sweep``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import parallel as par
from repro.core.pipeline import (make_pipelined_block_fn,
                                 measure_bubble_fraction, pipeline_apply)


def measure_bubble(cfg: ModelConfig, strat, topology,
                   seq_len: int = 128, mb_rows: int = 2,
                   n_iter: int = 3) -> dict:
    """Measured vs predicted bubble for ``strat`` (pp > 1) on live devices.

    The bubble is a property of the (P, M) schedule, not of model scale,
    so callers may pass a ``reduced()`` config to keep the probe cheap —
    the per-tick time only needs to dominate dispatch overhead.
    """
    assert strat.pp > 1, "bubble probe needs a pipeline strategy"
    shape = ShapeConfig("pp-probe", seq_len,
                        mb_rows * strat.microbatches * strat.grad_accum,
                        "train")
    plan = strat.to_plan(cfg, topology, shape)
    rt = par.make_runtime(
        cfg, plan, shape, param_dtype=jnp.float32,
        compute_dtype=jnp.float32, remat=False,
        attn_min_chunked_len=max(2048, seq_len + 1))
    rt_stage = dataclasses.replace(rt, constrain=None, gather_params=None)
    stage_fn = make_pipelined_block_fn(cfg, rt_stage)

    from repro.models import transformer as tfm
    from repro.models.layers import rope_angles
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    blocks = params["blocks"][0]
    rope = None
    if cfg.rope == "rope":
        pos = jnp.arange(seq_len, dtype=jnp.int32)[None]
        rope = rope_angles(pos, cfg.head_dim_, cfg.rope_theta)

    def step_for_m(m: int):
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (m, mb_rows, seq_len, cfg.d_model))

        def loss(p):
            out, _aux = pipeline_apply(stage_fn, {"layers": p}, x, plan.mesh,
                                       plan.pipe, extras=rope,
                                       batch_axes=tuple(plan.dp))
            return jnp.sum(out ** 2)

        with par.use_mesh(plan.mesh):
            fn = jax.jit(jax.value_and_grad(loss))

            def run():
                with par.use_mesh(plan.mesh):
                    return fn(blocks)

            return run

    with par.use_mesh(plan.mesh):
        rec = measure_bubble_fraction(step_for_m, strat.pp,
                                      strat.microbatches, n_iter=n_iter)
    rec.update(probe_cfg=cfg.name, probe_seq_len=seq_len,
               probe_mb_rows=mb_rows)
    return rec
