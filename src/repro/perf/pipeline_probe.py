"""Execute a pipeline schedule and *measure* its bubble fraction.

The cost model charges each pipeline schedule its analytic bubble
(``costmodel.step_time`` / ``pipeline.bubble_fraction``): (P-1)/(M+P-1)
for gpipe and 1f1b (1F1B reorders the bubble to cap activation memory,
it does not shrink it), (P-1)/(vM+P-1) for interleaved '1f1b_i<v>',
2(P-1)/(3M+2P-2) for zero-bubble 'zb'.  This probe validates those terms
against execution: it runs the exact ``pipeline_apply`` lowering a
``Strategy(pp>1)`` trains with (fwd + bwd, real stage params, the
strategy's own schedule) at fixed microbatch *size* for M and 2M
microbatches, fits t(M) = t_tick * (ticks_per_mb * M + drain) + overhead
(``measure_bubble_fraction`` divides the slope by the schedule's
per-microbatch tick coefficient — v for interleaved, 3 for zb), and
reports

    bubble_measured = drain * t_tick / t(M)

with the schedule's drain numerator (2(P-1) for zb, else P-1).  The
record carries ``virtual_stages`` so artifacts can re-check the
interleaved probe against (P-1)/(vM+P-1).

A non-increasing two-point fit (noisy host) is flagged
``fit_unreliable`` instead of masquerading as a clean 0.0 measurement.

Used by ``launch/dryrun.py --measure_bubble`` (written into the dryrun
artifact next to the prediction) and ``benchmarks/run.py --pp-sweep``
(which sweeps pp x schedule).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import parallel as par
from repro.core.pipeline import (make_pipelined_block_fn,
                                 measure_bubble_fraction, pipeline_apply)
from repro.models import transformer as tfm


def measure_bubble(cfg: ModelConfig, strat, topology,
                   seq_len: int = 128, mb_rows: int = 2,
                   n_iter: int = 3) -> dict:
    """Measured vs predicted bubble for ``strat`` (pp > 1) on live devices.

    The bubble is a property of the (P, M, schedule) tick table, not of
    model scale, so callers may pass a ``reduced()`` config to keep the
    probe cheap — the per-tick time only needs to dominate dispatch
    overhead.
    """
    assert strat.pp > 1, "bubble probe needs a pipeline strategy"
    if strat.ep > 1:
        # the in-stage expert all-to-all needs the probe's synthetic
        # microbatch sharded over (data, expert) — round the row count up
        # to the batch-axis group size (to_plan enforces the same)
        g = strat.dp_degree(topology)
        mb_rows = -(-mb_rows // g) * g
    shape = ShapeConfig("pp-probe", seq_len,
                        mb_rows * strat.microbatches * strat.grad_accum,
                        "train")
    plan = strat.to_plan(cfg, topology, shape)
    rt = par.make_runtime(
        cfg, plan, shape, param_dtype=jnp.float32,
        compute_dtype=jnp.float32, remat=False,
        attn_min_chunked_len=max(2048, seq_len + 1))
    # the exact stage runtime the forward path builds (manual tp/cp axes,
    # token-sharding stat axes, in-stage ep_manual MoE dispatch)
    rt_stage = tfm.pipeline_stage_runtime(rt, mb_rows)
    stage_fn = make_pipelined_block_fn(cfg, rt_stage)

    from repro.models.layers import rope_angles
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    blocks = params["blocks"][0]
    stage_params = {"layers": blocks}
    pspecs = tfm.pipeline_stage_param_specs(rt, stage_params)
    rope = None
    if cfg.rope == "rope":
        pos = jnp.arange(seq_len, dtype=jnp.int32)[None]
        rope = rope_angles(pos, cfg.head_dim_, cfg.rope_theta)

    def step_for_m(m: int):
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (m, mb_rows, seq_len, cfg.d_model))

        def loss(p):
            out, _aux = pipeline_apply(stage_fn, {"layers": p}, x, plan.mesh,
                                       plan.pipe, extras=rope,
                                       batch_axes=tuple(plan.dp),
                                       schedule=strat.sched,
                                       param_specs=pspecs,
                                       seq_axis=rt.pipeline_cp_axis,
                                       tp_axis=rt.pipeline_tp_axis)
            return jnp.sum(out ** 2)

        with par.use_mesh(plan.mesh):
            fn = jax.jit(jax.value_and_grad(loss))

            def run():
                with par.use_mesh(plan.mesh):
                    return fn(blocks)

            return run

    with par.use_mesh(plan.mesh):
        rec = measure_bubble_fraction(step_for_m, strat.pp,
                                      strat.microbatches, n_iter=n_iter,
                                      sched=strat.sched)
    rec.update(probe_cfg=cfg.name, probe_seq_len=seq_len,
               probe_mb_rows=mb_rows)
    return rec
