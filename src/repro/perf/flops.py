"""Analytic FLOP accounting per (architecture, input shape, mode).

``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies once, so
HLO FLOPs structurally undercount scanned models.  Since every einsum in
this codebase is known, we account FLOPs in closed form instead:
``compiled_flops`` models what the compiled step actually executes
(including causal-attention triangularity, MoE capacity slop, remat
recompute), while ``model_flops`` is the textbook 6·N·D (or 2·N per token)
the paper's MFU definition uses.  Their ratio exposes remat / routing /
attention overheads — exactly what §Roofline asks for.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.rwkv6 import TD_RANK, TM_RANK


def _attn_layer(cfg: ModelConfig, T: int, s_eff: float) -> float:
    d, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim_
    proj = 2 * T * d * (H * hd + 2 * Kv * hd) + 2 * T * H * hd * d
    scores = 2 * T * s_eff * H * hd * 2          # QK^T and PV
    return proj + scores


def _rwkv_layer(cfg: ModelConfig, T: int, chunk: int, decode: bool) -> float:
    d = cfg.d_model
    H, N = cfg.rwkv_heads, cfg.rwkv_head_dim
    proj = 5 * 2 * T * d * d
    lora = 2 * T * d * (5 * TM_RANK) * 2 + 2 * T * d * TD_RANK * 2
    if decode:
        wkv = T * H * (4 * N * N)
    else:
        # per chunk/head: qp kp^T (2C^2 N) + A v (2C^2 N) + qp S (2C N^2)
        # + tail update (2C N^2)
        wkv = T * H * (4 * chunk * N + 4 * N * N)
    return proj + lora + wkv


def _mamba_layer(cfg: ModelConfig, T: int) -> float:
    d = cfg.d_model
    mc = cfg.mamba
    di = mc.expand * d
    dtr = mc.dt_rank or -(-d // 16)
    proj = 2 * T * d * 2 * di + 2 * T * di * (dtr + 2 * mc.d_state) \
        + 2 * T * dtr * di + 2 * T * di * d
    conv = 2 * mc.d_conv * T * di
    scan = 6 * T * di * mc.d_state
    return proj + conv + scan


def _ffn_layer(cfg: ModelConfig, i: int, T: int) -> float:
    d = cfg.d_model
    mult = 3 if cfg.glu else 2
    if cfg.layer_kind(i) == "rwkv6":
        return 2 * T * d * cfg.d_ff * 2 + 2 * T * d * d   # k/v + receptance
    if cfg.is_moe_layer(i):
        m = cfg.moe
        routed_tokens = T * m.top_k * m.capacity_factor   # capacity slop incl.
        routed = 2 * routed_tokens * d * m.expert_d_ff * mult
        shared = 2 * T * d * (m.n_shared_experts * m.expert_d_ff) * mult
        router = 2 * T * d * m.n_experts
        return routed + shared + router
    dff = cfg.dense_d_ff or cfg.d_ff
    return 2 * T * d * dff * mult


def forward_flops(cfg: ModelConfig, shape: ShapeConfig,
                  rwkv_chunk: int = 64) -> float:
    """One forward pass over the global batch."""
    decode = shape.mode == "decode"
    B, S = shape.global_batch, shape.seq_len
    T = B * (1 if decode else S)
    if decode:
        ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
        s_eff = ctx
    else:
        s_eff = (S + 1) / 2
        if cfg.sliding_window:
            s_eff = min(s_eff, cfg.sliding_window)

    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            total += _attn_layer(cfg, T, s_eff)
        elif kind == "rwkv6":
            total += _rwkv_layer(cfg, T, rwkv_chunk, decode)
        else:
            total += _mamba_layer(cfg, T)
        total += _ffn_layer(cfg, i, T)
    total += 2 * T * cfg.d_model * cfg.vocab_size        # lm head
    return total


def compiled_flops(cfg: ModelConfig, shape: ShapeConfig, remat: bool = True
                   ) -> float:
    """FLOPs the compiled step executes: fwd(+bwd)(+remat recompute)."""
    fwd = forward_flops(cfg, shape)
    if shape.mode != "train":
        return fwd
    factor = 3.0 + (1.0 if remat else 0.0)               # fwd + 2x bwd + remat
    return fwd * factor


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Paper/MFU convention: 6·N_active·tokens (train), 2·N_active (infer)."""
    n = cfg.active_param_count()
    decode = shape.mode == "decode"
    tokens = shape.global_batch * (1 if decode else shape.seq_len)
    return (2.0 if shape.mode != "train" else 6.0) * n * tokens
