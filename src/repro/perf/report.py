"""Assemble EXPERIMENTS.md from dry-run records, roofline analysis, and
benchmark CSVs.  §Perf prose lives in results/perf_log.md (hand-written
during the hillclimb iterations) and is inlined verbatim.

    PYTHONPATH=src python -m repro.perf.report > EXPERIMENTS.md

All ``results/...`` inputs resolve against the repo root (perf/paths.py),
so the report builds identically from any working directory; a build
that matches **zero** ok dry-run records exits non-zero instead of
silently emitting empty tables.
"""
from __future__ import annotations

import csv
import glob
import json
import os
import sys

from repro.perf import roofline
from repro.perf.paths import results_path

# counts every ok dryrun record seen while building; main() refuses to
# emit a report built from nothing
_N_OK_DRYRUN = 0


def _dryrun_table(mesh: str) -> str:
    global _N_OK_DRYRUN
    rows = []
    for path in sorted(glob.glob(results_path("dryrun",
                                              f"*_{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"skipped: {r['reason']} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"**ERROR** {r.get('error','')[:80]} |")
            continue
        _N_OK_DRYRUN += 1
        mem = r["memory"]
        per_dev_gib = (mem["argument_bytes_per_device"]
                       + mem["temp_bytes_per_device"]) / 2**30
        coll = r.get("collective_bytes_total", 0)
        plan = r.get("plan", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {plan.get('attn','?')} "
            f"| {r['compile_s']:.0f}s | {per_dev_gib:.1f} "
            f"| {coll:.2e} | ok |")
    hdr = ("| arch | shape | attn plan | compile | bytes/dev GiB | "
           "collective B | status |\n|---|---|---|---|---|---|---|\n")
    return hdr + "\n".join(rows) + "\n"


def _collective_detail(mesh: str) -> str:
    out = ["| arch | shape | all-gather | all-reduce | reduce-scatter | "
           "all-to-all | collective-permute |",
           "|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(results_path("dryrun",
                                              f"*_{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        c = r.get("collectives", {})

        def b(k):
            v = c.get(k, {}).get("bytes", 0)
            return f"{v:.2e}" if v else "0"
        out.append(f"| {r['arch']} | {r['shape']} | {b('all-gather')} | "
                   f"{b('all-reduce')} | {b('reduce-scatter')} | "
                   f"{b('all-to-all')} | {b('collective-permute')} |")
    return "\n".join(out) + "\n"


def _benchmark_summaries() -> str:
    out = []
    for path in sorted(glob.glob(results_path("benchmarks", "*.csv"))):
        name = os.path.basename(path)[:-4]
        with open(path) as f:
            rows = list(csv.reader(f))
        out.append(f"### {name}\n")
        out.append("| " + " | ".join(rows[0]) + " |")
        out.append("|" + "---|" * len(rows[0]))
        for row in rows[1:]:
            out.append("| " + " | ".join(row) + " |")
        out.append("")
    return "\n".join(out) + "\n"


def _pipeline_sweep() -> str:
    """§Schedule-frontier table from BENCH_pipeline.json: the extended
    pp x {gpipe,1f1b,1f1b_i<v>,zb} x overlap sweep with per-schedule
    bubble (predicted + measured fit) and peak memory (cost model +
    compiled-executable memory analysis)."""
    path = results_path("benchmarks", "BENCH_pipeline.json")
    if not os.path.exists(path):
        return "_(run `python benchmarks/run.py --pp-sweep` first)_\n"
    with open(path) as f:
        bench = json.load(f)

    def _mib(v):
        return f"{v / 2**20:.0f}" if v is not None else "—"

    def _frac(v):
        return f"{v:.3f}" if v is not None else "—"

    out = [f"Backend `{bench.get('backend', '?')}`, "
           f"arch `{bench.get('arch', '?')}`.  Wall time on CPU hosts is a "
           "regression signal; the schedule-comparable columns are the "
           "bubble fraction (hardware-free) and the peak-memory pair — "
           "predicted (cost model in-flight term) next to measured "
           "(compiled executable temp bytes).\n",
           "| spec | sched | v | ovl | bubble pred | bubble meas | "
           "mem pred MiB | mem meas MiB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in bench.get("rows", []):
        flag = "!" if r.get("fit_unreliable") else ""
        out.append(
            f"| {r['spec']} | {r.get('sched', '—')} "
            f"| {r.get('virtual_stages', 1)} "
            f"| {'on' if r.get('overlap') else 'off'} "
            f"| {_frac(r.get('bubble_predicted'))} "
            f"| {_frac(r.get('bubble_measured'))}{flag} "
            f"| {_mib(r.get('predicted_peak_memory_bytes'))} "
            f"| {_mib(r.get('measured_temp_bytes'))} |")
    out.append("\n`!` marks a `fit_unreliable` bubble fit (non-increasing "
               "two-point measurement on a noisy host); `—` means the "
               "backend reported no executable memory analysis.\n")
    return "\n".join(out) + "\n"


def _perf_log() -> str:
    path = results_path("perf_log.md")
    if os.path.exists(path):
        with open(path) as f:
            return f.read()
    return "_(perf iteration log pending)_\n"


HEADER = """# EXPERIMENTS

Reproduction of **Hardware Scaling Trends and Diminishing Returns in
Large-Scale Distributed Training** (Fernandez et al., 2024) on the TPU v5e
target (256-chip pod / 2-pod meshes), CPU-validated.  See DESIGN.md for the
architecture of the framework and the GPU->TPU adaptation; this file holds
the experimental evidence.

Hardware constants for all derived numbers: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI per chip; v5e HBM capacity 16 GiB.

## §Paper-claims — cost-model reproduction of the paper's findings

The analytical cost model (`core/costmodel.py`) was calibrated once
(kernel efficiencies + inter-node latency + prefetch depth; see DESIGN.md)
and then evaluated against the paper's headline numbers:

| claim (paper §) | paper | this repro | status |
|---|---|---|---|
| Weak scaling: TFLOPS/WPS drop, 128->2048 H100s (§4.1) | −37.22% | −38.6% | ✅ |
| Per-GPU power nearly flat over the same sweep (§4.1) | −5.87% (658→620 W) | −5.8% | ✅ |
| TP 2–4 beats pure FSDP at 2048 GPUs, WPS gain (§5) | +52.6% | +46.6% (tp=4) | ✅ (direction + magnitude) |
| Optimal-strategy MFU, H100 256 GPUs (§4.4) | 40.77% | 45.3% | ✅ (≈) |
| Optimal-strategy MFU, A100 256 GPUs (§4.4) | 59.67% | 59.4% | ✅ |
| FSDP unavoidably comm-bound beyond ~128 GPUs (§5) | qualitative | exposed comm 0 at 8 GPUs, grows monotonically past 128 | ✅ |
| AllGather ring busbw decays with world size; tree AllReduce does not (Fig 2) | qualitative | property-tested (`test_costmodel.py`) | ✅ |
| Longer context -> better overlap, higher MFU & power efficiency (§4.6) | qualitative | reproduced (fig9 benchmark) | ✅ |
| Memory per GPU falls with DP degree, saturating (Fig 14) | qualitative | reproduced (fig14 benchmark) | ✅ |

Residuals: (a) the model reproduces the 2048-GPU TP flip but at 256 GPUs
its optimum stays at tp=1 (paper Fig 6 already sees tp=2 winning at 256);
(b) the exposure knee sits at ~1024 GPUs rather than just past 128 — the
calibration concentrates the measured 128→2048 throughput drop near the
latency-bound transition.  Both trades buy exactness on the weak-scaling,
power, and MFU anchors.  All anchors are enforced as tests
(`tests/test_costmodel.py::test_claim_*`).

"""

SECTION_NOTES = """
Notes on conventions:
* *collective B* is the trip-count-scaled sum of collective-op result bytes
  in the compiled HLO (`perf/hlo.py`); lax.scan bodies are multiplied by
  their `known_trip_count` — a naive line scan undercounts ~n_layers x.
* FLOPs are analytic (`perf/flops.py`): XLA's `cost_analysis()` counts scan
  bodies once, so compiled-HLO FLOPs structurally undercount; the analytic
  numbers model exactly the einsums the step executes (incl. remat, MoE
  capacity slop, causal triangularity).
* *bytes/dev* = argument + temp bytes from `compiled.memory_analysis()` —
  the fit-proof against the 16 GiB v5e HBM.
"""


def main():
    parts = [HEADER]
    parts.append("## §Dry-run — 10 arch x 4 shapes on the production meshes\n")
    parts.append("Every (architecture x shape) lowers **and compiles** for "
                 "both meshes; `long_500k` is skipped for pure full-attention "
                 "archs per DESIGN.md §4 (7 documented skips).\n")
    parts.append("### Single pod: (16, 16) = 256 chips, axes (data, model)\n")
    parts.append(_dryrun_table("pod16x16"))
    parts.append(SECTION_NOTES)
    parts.append("\n### Multi-pod: (2, 16, 16) = 512 chips, axes "
                 "(pod, data, model), HSDP across pods\n")
    parts.append(_dryrun_table("pod2x16x16"))
    parts.append("""
**HSDP vs fully-sharded 2D across pods** (`--dp_mode fsdp2d`, tagged runs):
sharding params over (pod, data) instead of replicating across pods halves
persistent parameter/optimizer state (granite-20b args 0.80 → 0.40
GiB/chip; qwen3 0.05 → 0.03) at nearly identical collective volume in
the compiled HLO (granite 5.114e11 → 5.107e11 B) — *but* the FSDP gathers
then cross the DCN pod boundary, which the cost model prices ~8× slower
per rank than ICI; HSDP therefore stays the default (the paper's
hierarchical-sharding recommendation, §6), with fsdp2d available when
capacity, not bandwidth, binds.
""")
    parts.append("\n### Collective mix per pair (single pod, bytes)\n")
    parts.append(_collective_detail("pod16x16"))

    parts.append("\n## §Roofline — three-term analysis per pair "
                 "(single pod, baseline)\n")
    rows = roofline.table(mesh="pod16x16")
    parts.append(roofline.markdown(rows))
    parts.append("""
Reading the table: decode shapes are uniformly **memory-bound** (KV/state
cache + weight streaming per token — the paper's asymmetric-hardware point
applies: more FLOPs would not help), train/prefill shapes are
**compute-bound** at this scale, with collective terms between ~0.5% and
~10% of the compute term (largest for the smallest model, qwen3-0.6b —
the paper's small-per-device-workload regime; see §Perf pair 2).  A
256-chip v5e pod with FSDP x TP is therefore *not yet* communication-
bound, consistent with the paper's finding that exposure begins beyond
~128 fast-interconnect devices: the v5e pod keeps the whole FSDP group on
ICI, and the cost model's `tpu_v5e_transfer` benchmark shows the exposure
appearing across the pod (DCN) boundary instead.  `6ND/compiled` < 1
quantifies remat (+1 fwd), MoE capacity slop (cf=1.25), attention
quadratic terms, and dense-layer overheads per arch.
""")

    opt_rows = roofline.table(mesh="pod16x16", tag="opt")
    if opt_rows:
        parts.append("\n### Optimized configurations (post-§Perf, tagged `opt`)\n")
        parts.append("Paper-faithful baselines above; the beyond-paper "
                     "optimized runs (scatter-free MoE dispatch + per-arch "
                     "gradient accumulation + SP ablation) below — both "
                     "recorded separately per the methodology:\n")
        parts.append(roofline.markdown(opt_rows))

    parts.append("\n## §Perf — hillclimbing log (3 selected pairs)\n")
    parts.append(_perf_log())

    parts.append("\n## §Benchmarks — per-figure outputs (cost model)\n")
    parts.append(_benchmark_summaries())
    parts.append("\n## §Schedule-frontier — pp x schedule x overlap sweep\n")
    parts.append(_pipeline_sweep())
    parts.append("\n## §Telemetry — measured-run artifacts\n")
    parts.append(
        "Instrumented runs (`--trace`, `--metrics_jsonl`, "
        "`--drift_report`; `benchmarks/run.py --drift-report`) write "
        "under `results/telemetry/`: Chrome-trace/Perfetto JSONs of "
        "host spans, JSONL event streams (schema-checked in CI via "
        "`python -m repro.telemetry`), and drift reports comparing the "
        "cost model's per-term step-time decomposition against "
        "measured windows (`predicted_over_measured` per "
        "compute/collective/bubble term).  See README \"Observability\".\n")
    if _N_OK_DRYRUN == 0:
        print("ERROR: no ok dryrun records matched under "
              f"{results_path('dryrun')} — run "
              "`python -m repro.launch.dryrun` first (the report would "
              "be built entirely from empty tables)", file=sys.stderr)
        raise SystemExit(1)
    print("\n".join(parts))


if __name__ == "__main__":
    main()
