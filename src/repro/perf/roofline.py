"""Three-term roofline analysis per (architecture x input shape x mesh).

Reads the dry-run records (results/dryrun/*.json), derives:

  compute term    = FLOPs / (chips * hw.flops_bf16)     [analytic-compiled]
  memory term     = HBM bytes / (chips * hw.hbm_bw)     [analytic, perf/bytes]
  collective term = collective bytes / (chips * hw.intra_bw / hw.rings)
                    [trip-count-scaled HLO parse, perf/hlo]

and reports, per pair: the three terms in seconds, the dominant bottleneck,
MODEL_FLOPS = 6·N_active·D (2·N_active per token at inference), the
MODEL/COMPILED flop ratio (remat / routing / attention overhead), and the
one-line lever that would move the dominant term.

The peaks come from a ``costmodel.Hardware`` profile (default: the
paper's TPU v5e) instead of module constants, so the roofline can never
drift from the calibrated analytic model the planner prices with — they
had already diverged once.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.core import costmodel as cm
from repro.perf import bytes as bytes_lib
from repro.perf import flops as flops_lib
from repro.perf.paths import from_root

DEFAULT_HW = cm.HARDWARE["TPUv5e"]


def _peaks(hw: Optional[cm.Hardware]):
    """(flops/s, HBM B/s, per-link B/s) for one chip of ``hw``."""
    hw = hw or DEFAULT_HW
    return hw.flops_bf16, hw.hbm_bw, hw.intra_bw / hw.rings

LEVERS = {
    "compute": "raise achieved matmul efficiency (Pallas flash/WKV kernels, "
               "larger per-chip tiles) or cut remat recompute",
    "memory": "cut HBM traffic: fuse elementwise chains, keep weights "
              "resident across microbatches, shrink optimizer/cache dtypes",
    "collective": "shrink the FSDP group (model parallelism, per the paper) "
                  "or overlap: the term is ICI-bound, not compute-bound",
}


def load_records(out_dir: str = "results/dryrun", mesh: str = "pod16x16",
                 tag: str = "") -> List[Dict]:
    recs = []
    suffix = f"_{mesh}" + (f"_{tag}" if tag else "") + ".json"
    # relative out_dirs anchor at the repo root, not the cwd — running
    # the roofline from elsewhere must not silently find zero records
    for path in sorted(glob.glob(os.path.join(from_root(out_dir),
                                              "*" + suffix))):
        base = os.path.basename(path)[: -len(suffix)]
        if not tag and len(base.split("_")) > 2 and base.count("_") > 1:
            pass
        with open(path) as f:
            rec = json.load(f)
        if tag and rec.get("tag", tag) != tag:
            continue
        recs.append(rec)
    # drop tagged files when untagged requested
    if not tag:
        recs = [r for r in recs if "_opt" not in json.dumps(r.get("mesh", ""))]
    return recs


def roofline_row(rec: Dict,
                 hw: Optional[cm.Hardware] = None) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    remat = shape.mode == "train"
    peak_flops, hbm_bw, link_bw = _peaks(hw)

    flops = rec.get("flops_compiled_analytic") or \
        flops_lib.compiled_flops(cfg, shape, remat=remat)
    t_compute = flops / (chips * peak_flops)

    hbm = bytes_lib.hbm_bytes_per_device(cfg, shape, chips, remat=remat)
    t_memory = hbm / hbm_bw

    coll = rec.get("collective_bytes_total", 0)
    t_coll = coll / (chips * link_bw)

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_fl = rec.get("flops_model_6nd") or flops_lib.model_flops(cfg, shape)
    bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "plan": rec.get("plan", {}).get("attn", "?"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": model_fl, "compiled_flops": flops,
        "useful_ratio": model_fl / flops if flops else 0.0,
        "roofline_step_s": bound,
        "roofline_mfu": model_fl / bound / (chips * peak_flops) if bound else 0,
        "hardware": (hw or DEFAULT_HW).name,
        "temp_gib": rec["memory"]["temp_bytes_per_device"] / 2**30,
        "arg_gib": rec["memory"]["argument_bytes_per_device"] / 2**30,
        "lever": LEVERS[dominant],
    }


def table(out_dir: str = "results/dryrun", mesh: str = "pod16x16",
          tag: str = "", hw: Optional[cm.Hardware] = None) -> List[Dict]:
    rows = []
    for rec in load_records(out_dir, mesh, tag):
        row = roofline_row(rec, hw=hw)
        if row:
            rows.append(row)
    return rows


def markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | plan | compute s | memory s | collective s | "
           "dominant | 6ND/compiled | roofline MFU | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['plan']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_mfu']:.2f} "
            f"| {r['temp_gib']:.1f} |\n")
    return "".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--hardware", default="TPUv5e",
                    choices=sorted(cm.HARDWARE))
    args = ap.parse_args()
    rows = table(args.out, args.mesh, args.tag,
                 hw=cm.HARDWARE[args.hardware])
    if not rows:
        import sys
        print(f"ERROR: no ok dryrun records under {from_root(args.out)} "
              f"for mesh {args.mesh!r}"
              + (f" tag {args.tag!r}" if args.tag else "")
              + " — run `python -m repro.launch.dryrun` first",
              file=sys.stderr)
        raise SystemExit(1)
    print(markdown(rows))
    for r in rows:
        if r["dominant"] != "compute":
            print(f"  -> {r['arch']}/{r['shape']}: {r['dominant']}-bound; "
                  f"{r['lever']}")


if __name__ == "__main__":
    main()
