"""Parse collective-communication volume out of compiled HLO text.

``cost_analysis()`` does not report collective bytes, so the roofline's
collective term is derived here.  Because lax.scan lowers to HLO while
loops whose bodies appear once in the text, a naive line scan undercounts
by the trip count; ``collective_stats`` therefore walks the computation
graph and multiplies while-body contributions by the
``known_trip_count`` annotation XLA attaches to each loop.

Byte convention: for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op we sum the byte size of the *result*
shapes (async ``-start`` counted once, ``-done`` ignored).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast",
               "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# tuple-typed params nest parentheses, so match greedily up to '->'
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{$")
_COLL_RE = re.compile(
    r"=\s*(.+?)\s+(" + "|".join(COLLECTIVES) + r")(-start)?\(")
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count...?.?.n.:.?"?(\d+)')
_CALL_RE = re.compile(r"\b(?:call|async-start)\(.*?to_apply=%?([\w.\-]+)")
_COND_RE = re.compile(
    r"(?:true_computation=%?([\w.\-]+).*?false_computation=%?([\w.\-]+)"
    r"|branch_computations=\{([^}]*)\})")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _COMP_HDR_RE.match(line) if (line.endswith("{")
                                         and not raw.startswith(" ")) else None
        if m:
            cur = m.group(1)
            comps[cur] = []
            if raw.startswith("ENTRY"):
                entry = cur
            continue
        if line == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry is None and comps:
        entry = next(iter(comps))
    comps["__entry__"] = [entry]  # type: ignore[list-item]
    return comps


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """-> {op_kind: {'bytes': loop-scaled result bytes, 'count': n_ops}}."""
    comps = _split_computations(hlo_text)
    entry = comps.pop("__entry__")[0]
    memo: Dict[str, Dict[str, Dict[str, float]]] = {}

    def walk(name: str) -> Dict[str, Dict[str, float]]:
        if name in memo:
            return memo[name]
        memo[name] = defaultdict(lambda: {"bytes": 0.0, "count": 0.0})
        acc = memo[name]
        for line in comps.get(name, ()):
            cm = _COLL_RE.search(line)
            if cm and not re.search(r"-done\(", line):
                acc[cm.group(2)]["bytes"] += _shape_bytes(cm.group(1))
                acc[cm.group(2)]["count"] += 1
            if _WHILE_RE.search(line):
                bm = _BODY_RE.search(line)
                if bm:
                    trip = 1
                    tm = _TRIP_RE.search(line)
                    if tm:
                        trip = int(tm.group(1))
                    for kind, v in walk(bm.group(1)).items():
                        acc[kind]["bytes"] += trip * v["bytes"]
                        acc[kind]["count"] += trip * v["count"]
                continue
            cm2 = _CALL_RE.search(line)
            if cm2:
                for kind, v in walk(cm2.group(1)).items():
                    acc[kind]["bytes"] += v["bytes"]
                    acc[kind]["count"] += v["count"]
            cm3 = _COND_RE.search(line)
            if cm3:
                branches = [b for b in cm3.groups()[:2] if b]
                if cm3.group(3):
                    branches = [s.strip().lstrip("%")
                                for s in cm3.group(3).split(",")]
                if branches:  # upper bound: the widest branch
                    best = None
                    for b in branches:
                        w = walk(b)
                        tot = sum(v["bytes"] for v in w.values())
                        if best is None or tot > best[0]:
                            best = (tot, w)
                    for kind, v in best[1].items():
                        acc[kind]["bytes"] += v["bytes"]
                        acc[kind]["count"] += v["count"]
        memo[name] = {k: dict(v) for k, v in acc.items()}
        return memo[name]

    return walk(entry) if entry else {}


def collective_stats_flat(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Line-scan without loop scaling (each op counted once)."""
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"bytes": 0.0, "count": 0.0})
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m and not re.search(r"-done\(", line):
            stats[m.group(2)]["bytes"] += _shape_bytes(m.group(1))
            stats[m.group(2)]["count"] += 1
    return dict(stats)


def total_collective_bytes(hlo_text: str) -> int:
    return int(sum(v["bytes"] for v in collective_stats(hlo_text).values()))
