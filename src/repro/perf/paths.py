"""Repo-root-anchored result paths.

The perf tools read ``results/...`` artifacts.  Globbing those relative
to the *current working directory* silently produces empty tables when
the tools run from anywhere but the repo root — so every consumer
resolves through here instead: relative paths anchor at the repository
root (three levels above this package: src/repro/perf -> repo).
"""
from __future__ import annotations

import os

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def from_root(*parts: str) -> str:
    """Join ``parts`` under the repo root; absolute inputs pass through."""
    path = os.path.join(*parts)
    if os.path.isabs(path):
        return path
    return os.path.join(REPO_ROOT, path)


def results_path(*parts: str) -> str:
    """``results/<parts...>`` anchored at the repo root."""
    return from_root("results", *parts)
