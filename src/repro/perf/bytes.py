"""Analytic per-device HBM traffic for the roofline memory term.

Like FLOPs (perf/flops.py), HLO bytes-accessed undercounts lax.scan bodies,
so HBM traffic is modeled in closed form.  Accounting convention (per
optimizer step / serve step, per device):

  * weights: each device reads the full (all-gathered) weight set once per
    forward, once per backward, and once more under remat; MoE reads only
    its local experts' slice plus the dispatched activations.
  * activations: each layer streams its (B_loc, S, d)-scale tensors a small
    constant number of times (read + write around each matmul);
  * optimizer: params + grads + both Adam moments read & written (fp32);
  * decode: the KV cache (or recurrent state) shard is read once per token
    and written at one slot — this dominates decode, which is why decode
    is memory-bound on every architecture.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

BF16 = 2
FP32 = 4
ACT_STREAMS = 8          # reads+writes of layer-scale activations per layer


def _cache_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                            n_devices: int) -> float:
    """Total KV/state cache bytes, already divided by devices (cache is
    sharded over the full mesh by the decode plan)."""
    B, S = shape.global_batch, shape.seq_len
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            s_eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
            total += B * s_eff * cfg.kv_heads * cfg.head_dim_ * 2 * BF16
        elif kind == "rwkv6":
            total += B * cfg.rwkv_heads * cfg.rwkv_head_dim ** 2 * FP32
        else:  # mamba
            di = cfg.mamba.expand * cfg.d_model
            total += B * di * cfg.mamba.d_state * FP32 \
                + B * (cfg.mamba.d_conv - 1) * di * BF16
    return total / n_devices


def hbm_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                         n_devices: int, remat: bool = True) -> float:
    B, S = shape.global_batch, shape.seq_len
    decode = shape.mode == "decode"
    train = shape.mode == "train"
    tokens_local = B * (1 if decode else S) / min(n_devices, B * (1 if decode else S))
    # activations are sharded over the whole mesh (data x model axes)
    tokens_per_dev = B * (1 if decode else S) / n_devices

    P = cfg.param_count() * BF16
    P_active = cfg.active_param_count() * BF16

    # ---- weights ----
    if decode:
        # every device reads its weight shard once per token step
        w_traffic = (P_active if cfg.moe.n_experts else P) / n_devices \
            * max(B / min(B, n_devices), 1.0)
        # (batched decode re-reads the shard once per local example group)
        w_traffic = max(w_traffic, P / n_devices)
    else:
        passes = (3 if not remat else 4) if train else 1
        w_traffic = P_active * passes if cfg.moe.n_experts else P * passes

    # ---- activations ----
    d = cfg.d_model
    act = cfg.n_layers * tokens_per_dev * d * BF16 * ACT_STREAMS
    if train:
        act *= 2.2          # backward re-streams + gradient tensors
    # logits
    act += tokens_per_dev * cfg.vocab_size * BF16 * (2 if train else 1)

    # ---- optimizer ----
    opt = 0.0
    if train:
        # read+write m, v (fp32), params (bf16), grads: all sharded
        opt = (2 * 2 * cfg.param_count() * FP32
               + 2 * cfg.param_count() * BF16
               + 2 * cfg.param_count() * FP32) / n_devices

    # ---- caches ----
    cache = 0.0
    if decode:
        cache = _cache_bytes_per_device(cfg, shape, n_devices) * 2  # read + update
    elif shape.mode == "prefill":
        cache = _cache_bytes_per_device(cfg, shape, n_devices)      # write once

    per_dev_weights = w_traffic if decode else w_traffic / 1  # full set read
    # In SPMD each device reads the gathered weights (full set) per pass:
    if not decode:
        per_dev = per_dev_weights + act + opt + cache
    else:
        per_dev = per_dev_weights + act + cache
    return per_dev
